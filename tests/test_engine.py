"""Unified Engine API: backend parity, shape-bucketed compile cache,
warm starts, and legacy-wrapper compatibility."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import disconnected_fraction, gsl_lpa, gve_lpa
from repro.engine import (
    CompileCache,
    Engine,
    EngineConfig,
    backend_names,
    choose_backend,
)
from repro.graphgen import erdos_renyi, karate_club, planted_partition

BACKENDS = ("segment", "tile", "sharded")

GRAPHS = {
    "er": lambda: erdos_renyi(180, 5.0, seed=11),
    "planted": lambda: planted_partition(6, 30, 0.3, 0.01, seed=3)[0],
    "karate": lambda: karate_club()[0],
}


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


def test_backends_registered():
    assert set(BACKENDS) <= set(backend_names())


@pytest.mark.parametrize("name", sorted(GRAPHS))
def test_backend_label_parity(name):
    """segment, tile, and sharded (exchange_every=1) produce identical
    compacted labels on the same graph."""
    g = GRAPHS[name]()
    eng = fresh_engine()
    results = {be: eng.fit(g, backend=be) for be in BACKENDS}
    ref = results["segment"]
    for be in BACKENDS:
        assert np.array_equal(results[be].labels, ref.labels), (name, be)
        assert results[be].lpa_iterations == ref.lpa_iterations, (name, be)
        assert results[be].num_communities == ref.num_communities
        assert float(disconnected_fraction(
            g, jnp.asarray(results[be].labels))) == 0.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_same_bucket_compiles_once(backend):
    """Two different graphs (different n, edges) in one shape bucket ->
    exactly one trace/compile per backend stage, and the second fit is a
    cache hit with a valid result.  Audited via the general trace-audit
    gate (tests/test_trace_audit.py runs the full-workload version)."""
    from repro.analysis import TraceAudit
    g1 = erdos_renyi(200, 5.0, seed=1)
    g2 = erdos_renyi(230, 5.0, seed=2)
    eng = fresh_engine(backend=backend)

    with TraceAudit() as audit:
        r1 = eng.fit(g1)
        r2 = eng.fit(g2)

    assert r1.bucket == r2.bucket
    assert not r1.cache_hit and r2.cache_hit
    audit.assert_no_excess()   # nothing traced twice, incl. the 2nd fit
    deltas = audit.deltas()
    assert {tag for tag, _ in deltas} == {f"{backend}:propagate",
                                          f"{backend}:split"}
    assert all(ctx == (backend, r1.bucket) for _, ctx in deltas)
    assert float(disconnected_fraction(g2, jnp.asarray(r2.labels))) == 0.0


def test_second_fit_bit_identical():
    g = erdos_renyi(150, 4.0, seed=9)
    eng = fresh_engine()
    r1 = eng.fit(g)
    r2 = eng.fit(g)
    assert r2.cache_hit
    assert np.array_equal(r1.labels, r2.labels)
    assert r1.lpa_iterations == r2.lpa_iterations


def test_legacy_wrappers_ride_the_engine():
    """gsl_lpa / gve_lpa are facades over the Engine (exact bucketing) and
    agree with a direct exact-bucket Engine fit."""
    g, _ = karate_club()
    eng = fresh_engine(bucketing="exact")
    res = eng.fit(g)
    legacy = gsl_lpa(g, split="lp")
    assert np.array_equal(legacy.labels, res.labels)
    assert legacy.lpa_iterations == res.lpa_iterations
    assert legacy.split_iterations == res.split_iterations
    assert legacy.lpa_seconds > 0 and legacy.split_seconds > 0
    none = gve_lpa(g)
    assert none.split_iterations == 0


@pytest.mark.parametrize("split", ["none", "lp", "lpp", "bfs_host"])
def test_split_methods_through_engine(split):
    g = erdos_renyi(120, 5.0, seed=6)
    res = fresh_engine(split=split).fit(g)
    assert res.labels.shape == (g.n,)
    assert res.labels.min() == 0
    if split != "none":
        assert float(disconnected_fraction(g, jnp.asarray(res.labels))) == 0.0


def test_warm_start_auto_keys_on_graph_fingerprint():
    """Regression: warm_start="auto" used to key on the vertex count
    alone, silently warm-starting from an *unrelated* graph of the same
    size.  It now keys on a structural fingerprint (n, m, offset/dst
    hashes)."""
    g1 = erdos_renyi(100, 4.0, seed=1)
    g2 = erdos_renyi(100, 4.0, seed=2)   # same n, different structure
    assert g1.n == g2.n
    eng = fresh_engine(warm_start="auto")
    r1 = eng.fit(g1)
    assert not r1.warm_started
    r2 = eng.fit(g2)
    assert not r2.warm_started, "warm-started from an unrelated graph"
    r3 = eng.fit(g2)
    assert r3.warm_started  # same structure -> warm start still applies


def test_fingerprint_precomputed_no_recompute_on_repeat_fits():
    """Regression: ``build_graph`` now fingerprints from the host-side
    CSR before device transfer, so warm_start="auto" fits (and
    StreamSession updates) never pay a device->host copy + CRC per fit.
    A lazy recompute inside fit would call zlib.crc32 — assert it
    doesn't."""
    from unittest import mock
    g = erdos_renyi(80, 4.0, seed=3)
    eng = fresh_engine(warm_start="auto")
    with mock.patch("zlib.crc32",
                    side_effect=AssertionError("fingerprint recomputed")):
        r1 = eng.fit(g)
        r2 = eng.fit(g)
    assert not r1.warm_started and r2.warm_started


def test_warm_start_auto_and_explicit():
    g, _ = planted_partition(8, 30, 0.3, 0.005, seed=5)
    eng = fresh_engine(warm_start="auto")
    r1 = eng.fit(g)
    assert not r1.warm_started
    r2 = eng.fit(g)  # previous labels re-used -> converges quickly
    assert r2.warm_started
    assert r2.lpa_iterations <= r1.lpa_iterations
    assert float(disconnected_fraction(g, jnp.asarray(r2.labels))) == 0.0

    cold = fresh_engine()
    r3 = cold.fit(g, init_labels=r1.labels)
    assert r3.warm_started
    assert float(disconnected_fraction(g, jnp.asarray(r3.labels))) == 0.0


def test_result_shape_and_metrics():
    g, _ = karate_club()
    res = fresh_engine(compute_metrics=True).fit(g)
    assert res.num_communities == len(set(res.labels.tolist()))
    assert set(res.timings) == {"prepare", "propagation", "split", "compact"}
    assert res.modularity is not None and res.modularity > 0.2
    assert res.disconnected_fraction == 0.0
    assert res.backend in BACKENDS


def test_auto_backend_selection_runs():
    g = erdos_renyi(64, 3.0, seed=2)
    cfg = EngineConfig(backend="auto")
    assert choose_backend(g, cfg) in BACKENDS
    res = Engine(cfg, cache=CompileCache()).fit(g)
    assert res.backend in BACKENDS


def test_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(backend="gpu-magic")
    with pytest.raises(ValueError):
        EngineConfig(split="fancy")
    with pytest.raises(ValueError):
        EngineConfig(exchange_every=0)
    g = erdos_renyi(40, 3.0, seed=1)
    with pytest.raises(ValueError):
        fresh_engine(split="lpp").fit(g, backend="sharded")
    with pytest.raises(ValueError):
        fresh_engine().fit(g, init_labels=np.full(g.n, g.n + 3))


# --- fused sweeps (fuse_sweeps) ---------------------------------------------

@pytest.mark.parametrize("split", ["lp", "lpp", "none"])
def test_fused_fit_parity_across_splits(split):
    """fuse_sweeps on vs off: identical labels AND iteration counts.
    The lazy-wake restructure defers each sub-sweep's wake to the next
    dispatch, so the fused path is bit-neutral by construction."""
    g = GRAPHS["er"]()
    base = fresh_engine(backend="tile", split=split, kernel_mode="ref",
                        fuse_sweeps="off").fit(g)
    fused = fresh_engine(backend="tile", split=split, kernel_mode="ref",
                         fuse_sweeps="on").fit(g)
    assert np.array_equal(fused.labels, base.labels), split
    assert fused.lpa_iterations == base.lpa_iterations
    assert fused.split_iterations == base.split_iterations
    # cross-backend: the segment oracle agrees with the fused tile run
    seg = fresh_engine(backend="segment", split=split).fit(g)
    assert np.array_equal(fused.labels, seg.labels), split


def test_fused_fit_parity_interpret():
    """Interpret mode runs the real fused kernel body on CPU."""
    g = GRAPHS["karate"]()
    base = fresh_engine(backend="tile", kernel_mode="interpret",
                        fuse_sweeps="off").fit(g)
    fused = fresh_engine(backend="tile", kernel_mode="interpret",
                         fuse_sweeps="on").fit(g)
    assert np.array_equal(fused.labels, base.labels)
    assert fused.lpa_iterations == base.lpa_iterations
    assert fused.split_iterations == base.split_iterations


def test_fused_fit_many_parity():
    """Batched dispatch threads the carried wake state per graph."""
    graphs = [erdos_renyi(120, 4.0, seed=s) for s in (1, 2, 3)]
    base = fresh_engine(backend="tile", kernel_mode="ref",
                        fuse_sweeps="off").fit_many(graphs)
    fused = fresh_engine(backend="tile", kernel_mode="ref",
                         fuse_sweeps="on").fit_many(graphs)
    for b, f in zip(base, fused):
        assert np.array_equal(f.labels, b.labels)
        assert f.lpa_iterations == b.lpa_iterations
        assert f.split_iterations == b.split_iterations
        assert f.batch_size == b.batch_size == 3
