"""Trace-audit gate: zero excess retraces across every execution path.

Generalizes the old single-case one-trace-per-bucket assertion into the
workload gate the ISSUE/CI run: solo cold, same-bucket reuse, warm
refits, batched dispatch, sharded, and out-of-core partitioned sweeps
all execute under one audit, and no (stage, backend, bucket) may trace
more than once.
"""
import pytest

from repro.analysis import ExcessRetraceError, TraceAudit, audit_workload
from repro.engine.cache import TRACE_LOG, current_trace_context, trace_context


def test_trace_context_attribution():
    assert current_trace_context() is None
    with trace_context("segment", (256, 2048, 128)):
        assert current_trace_context() == ("segment", (256, 2048, 128))
        with trace_context("tile", (8,)):
            assert current_trace_context() == ("tile", (8,))
        assert current_trace_context() == ("segment", (256, 2048, 128))
    assert current_trace_context() is None


def test_record_lands_in_current_context():
    before = TRACE_LOG.context_snapshot()
    with trace_context("fake-backend", (1, 2)):
        TRACE_LOG.record("fake-backend:stage")
    after = TRACE_LOG.context_snapshot()
    key = ("fake-backend:stage", ("fake-backend", (1, 2)))
    assert after.get(key, 0) - before.get(key, 0) == 1
    # plain per-tag counters keep working for existing tests
    assert TRACE_LOG.snapshot()["fake-backend:stage"] >= 1


def test_audit_detects_excess():
    with TraceAudit() as audit:
        with trace_context("fake-backend", (3, 4)):
            TRACE_LOG.record("fake-backend:stage")
            TRACE_LOG.record("fake-backend:stage")
    key = ("fake-backend:stage", ("fake-backend", (3, 4)))
    assert audit.excess() == {key: 2}
    report = audit.report()
    assert not report["ok"] and report["excess_contexts"] == 1
    with pytest.raises(ExcessRetraceError, match="fake-backend:stage"):
        audit.assert_no_excess()


def test_audit_single_trace_is_clean(tmp_path):
    with TraceAudit() as audit:
        with trace_context("fake-backend", (5, 6)):
            TRACE_LOG.record("fake-backend:stage")
    assert audit.excess() == {}
    report = audit.write_json(tmp_path / "audit.json")
    assert report["ok"] and (tmp_path / "audit.json").exists()


def test_workload_zero_excess_retraces():
    """The acceptance gate: solo + same-bucket + warm + batched + sharded
    + out-of-core, all under one audit, zero excess retraces."""
    audit = audit_workload()
    report = audit.report()
    assert report["ok"], report
    assert audit.excess() == {}
    # the workload genuinely exercised every dispatch family
    stages = {row["stage"] for row in report["contexts"]}
    for expected in ("segment:propagate", "segment:batch_propagate",
                     "segment:part_move", "segment:part_fused_move",
                     "tile:propagate", "tile:propagate_fused",
                     "tile:batch_propagate", "tile:batch_propagate_fused",
                     "tile:part_move", "tile:part_fused_move",
                     "sharded:propagate"):
        assert expected in stages, f"workload never traced {expected}"
    audit.assert_no_excess()
