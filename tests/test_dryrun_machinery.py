"""Dry-run machinery guard: lower+compile reduced cells on an 8-device host
mesh in a subprocess (the full 512-device sweep runs out-of-band; this test
keeps the machinery from rotting)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import json
import jax, jax.numpy as jnp
from repro.configs import reduced_config, input_specs
from repro.configs.base import SHAPES
from repro.launch.mesh import make_host_mesh
from repro.launch.dryrun import collective_bytes
from repro.train import steps as S
from repro.models import transformer as T

mesh = make_host_mesh((4, 2), ("data", "model"))
out = {}
for name, shape in [("yi-9b", "train_4k"), ("qwen2-moe-a2.7b", "train_4k"),
                    ("jamba-v0.1-52b", "long_500k")]:
    cfg = reduced_config(name)
    sp = SHAPES[shape]
    batch_abs = input_specs(cfg, shape)
    if sp.step == "train":
        step, rules, psh, osh = S.make_train_step(cfg, mesh, shape)
        params_abs = S.state_shardings(cfg, mesh, shape)[3]
        opt_abs = S.abstract_opt_state(cfg, params_abs)
        bsh = S.batch_shardings(cfg, mesh, shape, batch_abs)
        sds = lambda t, s: jax.tree.map(
            lambda a, ss: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=ss),
            t, s)
        lowered = step.lower(sds(params_abs, psh), sds(opt_abs, osh),
                             sds(batch_abs, bsh),
                             jax.ShapeDtypeStruct((), jnp.int32))
    else:
        step, rules, psh, csh = S.make_decode_step(cfg, mesh, shape)
        params_abs = S.state_shardings(cfg, mesh, shape)[3]
        caches_abs = T.init_decode_caches(cfg, sp.global_batch, sp.seq_len,
                                          abstract=True)
        sds = lambda t, s: jax.tree.map(
            lambda a, ss: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=ss),
            t, s)
        lowered = step.lower(sds(params_abs, psh), sds(caches_abs, csh),
                             batch_abs)
    comp = lowered.compile()
    from repro.parallel.compat import cost_analysis_dict
    cost = cost_analysis_dict(comp)
    coll = collective_bytes(comp.as_text(), loop_trips=cfg.n_groups)
    mem = comp.memory_analysis()
    out[f"{name}/{shape}"] = {
        "flops": float(cost.get("flops", -1)),
        "wire": float(coll["wire_bytes"]["total"]),
        "counts": coll["counts"],
        "arg_bytes": int(mem.argument_size_in_bytes),
    }
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dryrun_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_cells_compile_with_positive_flops(dryrun_results):
    for cell, r in dryrun_results.items():
        assert r["flops"] > 0, cell
        assert r["arg_bytes"] > 0, cell


def test_train_cells_have_collectives(dryrun_results):
    """Sharded train steps must communicate (grad reduce, TP gathers)."""
    for cell in ("yi-9b/train_4k", "qwen2-moe-a2.7b/train_4k"):
        r = dryrun_results[cell]
        assert r["wire"] > 0, (cell, r)
        assert sum(r["counts"].values()) > 0


def test_long_decode_compiles_with_sp_cache(dryrun_results):
    assert "jamba-v0.1-52b/long_500k" in dryrun_results
