"""Checkpoint manager: roundtrip, atomicity, keep-k, hash verify, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((8, 16)), "count": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    mgr.save(5, tree, extra={"data": {"seed": 0, "step": 5}})
    restored, step, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 5 and extra["data"]["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(1), blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]
    # no tmp dirs left behind
    assert not list(tmp_path.glob("tmp-*"))


def test_hash_verification(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(2, tree)
    # corrupt the payload
    payload = tmp_path / "step-2" / "arrays.npz"
    raw = bytearray(payload.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    payload.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        mgr.restore(tree)


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((8, 8))})


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
