"""Optimizer, schedule, and gradient-compression tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw_init, adamw_update, cosine_schedule, global_norm
from repro.optim.compress import dequantize_int8, ef_compress, quantize_int8


def test_adamw_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for step in range(300):
        grads = {"x": 2 * params["x"]}        # d/dx x^2
        params, state, _ = adamw_update(grads, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_grad_clipping():
    params = {"x": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"x": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(huge, state, params, lr=1e-3,
                                 clip_norm=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_bf16_state_dtype():
    params = {"x": jnp.zeros((4,), jnp.bfloat16)}
    state = adamw_init(params, jnp.bfloat16)
    assert state.m["x"].dtype == jnp.bfloat16
    g = {"x": jnp.ones((4,), jnp.bfloat16)}
    p2, s2, _ = adamw_update(g, state, params, lr=1e-2)
    assert p2["x"].dtype == jnp.bfloat16
    assert s2.v["x"].dtype == jnp.bfloat16


def test_cosine_schedule():
    lr0 = float(cosine_schedule(jnp.int32(0), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100))
    lrp = float(cosine_schedule(jnp.int32(10), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100))
    lre = float(cosine_schedule(jnp.int32(100), peak_lr=1e-3,
                                warmup_steps=10, total_steps=100))
    assert lr0 == 0.0
    assert lrp == pytest.approx(1e-3)
    assert lre == pytest.approx(1e-4, rel=0.05)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF compression: the *accumulated* compressed sum tracks the true sum
    (residual stays bounded) — the convergence-safety property."""
    rng = np.random.default_rng(1)
    err = jnp.zeros((64,))
    true_sum = np.zeros(64)
    comp_sum = np.zeros(64)
    for step in range(200):
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        true_sum += np.asarray(g)
        q, scale, err = ef_compress(g, err)
        comp_sum += np.asarray(dequantize_int8(q, scale))
    # residual = true - compressed must equal the carried error exactly
    np.testing.assert_allclose(true_sum - comp_sum, np.asarray(err),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(err)).max() < 0.2   # bounded, not growing


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
