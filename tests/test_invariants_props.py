"""Hypothesis half of the invariant suite (see tests/test_invariants.py):
the zero-internally-disconnected-communities guarantee on *generated*
graphs, across backends and split modes.  Marked ``slow`` — the dedicated
CI job runs ``-m slow`` with hypothesis installed; the default run skips
cleanly when it is absent.
"""
import numpy as np
import pytest

from repro.core.graph import build_graph
from repro.engine import Engine, EngineConfig

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow

SPLITS = ("lp", "lpp", "bfs_host")

# Module-level engines: every example reuses the same pow2-bucketed
# compiled plans, so the suite pays trace+compile once per (backend,
# split), not once per generated graph.
_ENGINES = {(be, sp): Engine(EngineConfig(backend=be, split=sp))
            for be in ("segment", "tile") for sp in SPLITS}


@st.composite
def edge_lists(draw):
    n = draw(st.integers(min_value=2, max_value=48))
    m = draw(st.integers(min_value=0, max_value=4 * n))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=m, max_size=m))
    return n, np.asarray(edges, dtype=np.int64).reshape(-1, 2)


@settings(max_examples=25, deadline=None)
@given(edge_lists())
def test_property_no_disconnected_communities(ne):
    n, edges = ne
    g = build_graph(edges, n=n)
    for (be, sp), eng in _ENGINES.items():
        res = eng.fit(g)
        assert res.check_connected(g) == 0.0, (be, sp)


@settings(max_examples=25, deadline=None)
@given(edge_lists())
def test_property_batched_matches_solo_and_stays_connected(ne):
    n, edges = ne
    g = build_graph(edges, n=n)
    eng = _ENGINES[("segment", "lp")]
    (batched,) = eng.fit_many([g])
    solo = eng.fit(g)
    assert np.array_equal(batched.labels, solo.labels)
    assert batched.check_connected(g) == 0.0
