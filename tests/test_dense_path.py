"""Dense (kernel-tile) LPA path == sparse (sort/segment) path, bit-exact."""
import numpy as np
import pytest

from repro.core import lpa_run, split_lp
from repro.core.dense import (
    lpa_run_dense,
    pad_graph,
    split_lp_dense,
)
from repro.graphgen import karate_club, planted_partition
from conftest import random_graph


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lpa_dense_equals_sparse(seed):
    g = random_graph(40 + seed * 17, 5.0, seed=seed, weighted=True)
    st_sparse = lpa_run(g)
    pg = pad_graph(g)
    lab_dense, iters = lpa_run_dense(pg)
    assert np.array_equal(np.asarray(st_sparse.labels),
                          np.asarray(lab_dense))
    assert int(st_sparse.iteration) == int(iters)


def test_split_dense_equals_sparse():
    for gf in (lambda: karate_club()[0],
               lambda: planted_partition(5, 30, 0.3, 0.01, seed=1)[0]):
        g = gf()
        st_ = lpa_run(g)
        sp = split_lp(g, st_.labels)
        pg = pad_graph(g)
        sd, _ = split_lp_dense(pg, st_.labels)
        assert np.array_equal(np.asarray(sp.labels), np.asarray(sd))


def test_dense_path_with_interpret_kernels():
    """Tile path driven through the actual Pallas kernel bodies."""
    g, _ = karate_club()
    pg = pad_graph(g)
    lab_ref, it_ref = lpa_run_dense(pg, mode="ref")
    lab_pal, it_pal = lpa_run_dense(pg, mode="interpret")
    assert np.array_equal(np.asarray(lab_ref), np.asarray(lab_pal))
    assert int(it_ref) == int(it_pal)
