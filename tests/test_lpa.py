"""LPA propagation-phase tests (paper Algorithm 3 lines 1-6)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lpa_run, modularity
from repro.core.lpa import lpa_move, lpa_move_reference
from repro.graphgen import karate_club, planted_partition, ring_of_cliques
from conftest import random_graph


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000), st.booleans())
def test_lpa_move_matches_dense_reference(n, seed, weighted):
    g = random_graph(n, 4.0, seed=seed, weighted=weighted)
    labels = jnp.arange(g.n, dtype=jnp.int32)
    active = jnp.ones(g.n, bool)
    for it in range(3):
        got, ch_a, dn_a = lpa_move(g, labels, active, it)
        want, ch_b, dn_b = lpa_move_reference(g, labels, active, it)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert int(dn_a) == int(dn_b)
        labels = got


def test_karate_quality():
    g, _ = karate_club()
    st_ = lpa_run(g)
    q = float(modularity(g, st_.labels))
    ncomm = len(set(np.asarray(st_.labels).tolist()))
    assert q > 0.30, q            # LPA literature: ~0.35 on karate
    assert 2 <= ncomm <= 8
    assert int(st_.iteration) < 20


def test_ring_of_cliques_exact():
    g = ring_of_cliques(8, 6)
    st_ = lpa_run(g)
    labels = np.asarray(st_.labels)
    # every clique uniform
    for q in range(8):
        block = labels[q * 6:(q + 1) * 6]
        assert len(set(block.tolist())) == 1
    assert len(set(labels.tolist())) == 8


def test_planted_partition_recovery():
    g, truth = planted_partition(8, 40, p_in=0.35, p_out=0.002, seed=3)
    st_ = lpa_run(g)
    q = float(modularity(g, st_.labels))
    assert q > 0.6
    # most blocks recovered as single communities
    labels = np.asarray(st_.labels)
    pure = sum(1 for b in range(8)
               if len(np.unique(labels[b * 40:(b + 1) * 40])) == 1)
    assert pure >= 5


def test_determinism():
    g, _ = karate_club()
    a = np.asarray(lpa_run(g).labels)
    b = np.asarray(lpa_run(g).labels)
    assert np.array_equal(a, b)


def test_convergence_tolerance():
    g, _ = karate_club()
    st_tight = lpa_run(g, tau=0.0, max_iterations=50)
    # converged fully: one more sweep changes nothing
    labels = st_tight.labels
    new, _, dn = lpa_move(g, labels, jnp.ones(g.n, bool),
                          st_tight.iteration * 2)
    # tau=0 stops when delta_n == 0 across a full iteration (2 sweeps);
    # a single extra even-parity sweep may still be non-zero only if the
    # loop hit max_iterations instead
    assert int(st_tight.iteration) < 50
    assert int(dn) == 0 or int(st_tight.iteration) == 50


def test_isolated_vertices_keep_labels():
    g = random_graph(30, 2.0, seed=9)
    st_ = lpa_run(g)
    deg = np.asarray(g.degrees())
    labels = np.asarray(st_.labels)
    iso = np.where(deg == 0)[0]
    assert np.array_equal(labels[iso], iso)
