"""Out-of-core partitioned detection: planning, budget, and bit-parity.

The acceptance contract this suite pins:
  * partitioned ``fit`` labels are **bit-identical** to in-core ``fit``
    for segment + tile across split modes (the sequential partition
    sweep against a shared snapshot reproduces every synchronous in-core
    sweep exactly);
  * halo sets exactly cover all cross-partition edges;
  * peak resident edge bytes never exceed the budget (ledger-asserted);
  * ``check_connected == 0`` still holds globally after the
    per-partition split + cross-partition unification.
"""

import numpy as np
import pytest

from conftest import random_graph
from repro.core.graph import build_graph
from repro.engine import CompileCache, Engine, EngineConfig
from repro.partition.ooc import (
    fit_out_of_core,
    in_core_edge_bytes,
    open_source,
)
from repro.partition.plan import (
    attach_halos,
    parse_bytes,
    plan_partitions,
)
from repro.partition.slices import (
    HaloLabelCache,
    InMemorySource,
    MemoryBudgetExceeded,
    MemoryLedger,
    SliceLoader,
    load_partition,
    slice_nbytes,
)

# Small enough that every (backend, split) combo compiles fast; sized so
# a tight budget forces a real multi-partition sweep with halos.
FIXTURES = {
    "random": lambda: random_graph(220, 4.0, seed=3),
    "communities": lambda: _planted(),
    # denser mix for the tile backend, whose (8, 128)-cell dense-tile
    # floor (~9 KB/partition) needs in-core bytes comfortably above it
    "tile_mix": lambda: random_graph(256, 10.0, seed=21),
}


def _planted():
    from repro.graphgen import planted_partition
    return planted_partition(8, 24, 0.3, 0.01, seed=4)[0]


def _row_ptr(graph):
    return np.asarray(graph.row_ptr)


def _tight_budget(graph, backend: str = "segment") -> int:
    """A budget well under the graph's in-core edge bytes, so the
    engine must partition (and the ledger has real work to bound).
    The tile backend's floor is one dense (8, d_bucket) tile."""
    from repro.partition.ooc import IN_CORE_EDGE_BYTES
    in_core = graph.m_pad * IN_CORE_EDGE_BYTES
    if backend == "tile":
        return max(in_core // 2, 20_000)
    return in_core // 3


# --- planning ---------------------------------------------------------------

def test_plan_covers_and_balances():
    g = random_graph(300, 5.0, seed=0)
    plan = plan_partitions(_row_ptr(g), num_partitions=7)
    assert plan.parts[0].lo == 0 and plan.parts[-1].hi == g.n
    for a, b in zip(plan.parts[:-1], plan.parts[1:]):
        assert a.hi == b.lo
    rp = _row_ptr(g)
    for p in plan.parts:
        assert p.e_lo == rp[p.lo] and p.e_hi == rp[p.hi]
    # degree balance: a window overshoots the ideal share by at most
    # one row's degree (rows are atomic)
    target = -(-plan.num_edges // plan.num_partitions)
    assert plan.max_part_edges <= target + int(np.max(rp[1:] - rp[:-1]))


def test_plan_by_max_edges_and_row_cap():
    g = random_graph(200, 6.0, seed=1)
    plan = plan_partitions(_row_ptr(g), max_edges=100)
    assert all(p.num_edges <= 100 + int(np.max(_row_ptr(g)[1:]
                                               - _row_ptr(g)[:-1]))
               for p in plan.parts)
    capped = plan_partitions(_row_ptr(g), max_edges=10 ** 9, max_vertices=16)
    assert all(p.size <= 16 for p in capped.parts)
    with pytest.raises(ValueError):
        plan_partitions(_row_ptr(g))
    with pytest.raises(ValueError):
        plan_partitions(_row_ptr(g), max_edges=10, num_partitions=3)


def test_halo_exactly_covers_cross_partition_edges():
    g = random_graph(150, 5.0, seed=2)
    src = np.asarray(g.src)[: g.num_edges]
    dst = np.asarray(g.dst)[: g.num_edges]
    plan = attach_halos(plan_partitions(_row_ptr(g), num_partitions=5),
                        lambda lo, hi: dst[lo:hi])
    for p in plan.parts:
        in_part = (src >= p.lo) & (src < p.hi)
        crossing = dst[in_part & ((dst < p.lo) | (dst >= p.hi))]
        assert set(p.halo.tolist()) == set(crossing.tolist())
        # sorted, unique, and disjoint from the owned range
        assert np.all(np.diff(p.halo) > 0)
        assert not np.any((p.halo >= p.lo) & (p.halo < p.hi))


def test_parse_bytes():
    assert parse_bytes(4096) == 4096
    assert parse_bytes("64MB") == 64_000_000
    assert parse_bytes("1GiB") == 1 << 30
    assert parse_bytes("1Gi") == 1 << 30   # common binary-unit spelling
    assert parse_bytes("2.5KB") == 2500
    for bad in ("sixty MB", "64XB", "1i"):
        with pytest.raises(ValueError):
            parse_bytes(bad)


# --- slices + ledger --------------------------------------------------------

def test_load_partition_reconstructs_global_edges():
    g = random_graph(120, 4.0, seed=5)
    src = np.asarray(g.src)[: g.num_edges]
    dst = np.asarray(g.dst)[: g.num_edges]
    source = InMemorySource(g)
    plan = attach_halos(plan_partitions(_row_ptr(g), num_partitions=4),
                        lambda lo, hi: source.window("dst", lo, hi))
    for p in plan.parts:
        res = load_partition(source, p)
        # local ids map back to exactly the window's global edges
        gsrc = res.local_ids[res.src]
        gdst = res.local_ids[res.dst]
        assert np.array_equal(gsrc, src[p.e_lo:p.e_hi])
        assert np.array_equal(gdst, dst[p.e_lo:p.e_hi])
        # local row_ptr spans the window
        assert res.row_ptr[0] == 0 and res.row_ptr[-1] == p.num_edges


def test_ledger_budget_is_hard():
    ledger = MemoryLedger(1000)
    ledger.acquire(800, "a")
    with pytest.raises(MemoryBudgetExceeded):
        ledger.acquire(300, "b")
    ledger.release(800)
    assert ledger.current == 0 and ledger.peak == 800


def test_loader_lru_stays_under_budget():
    g = random_graph(200, 5.0, seed=6)
    source = InMemorySource(g)
    plan = attach_halos(plan_partitions(_row_ptr(g), num_partitions=6),
                        lambda lo, hi: source.window("dst", lo, hi))
    from repro.partition.slices import slice_nbytes
    budget = max(slice_nbytes(p) for p in plan.parts) * 2
    ledger = MemoryLedger(budget)
    loader = SliceLoader(source, plan, ledger)
    for sweep in range(3):
        for i in range(plan.num_partitions):
            loader.load(i)
    assert ledger.peak <= budget
    assert loader.loads > plan.num_partitions  # tight budget => reloads
    loader.clear()
    assert ledger.current == 0


def test_loader_prefetch_stages_under_budget():
    """Round-robin sweeps with the next window staged: the ledger's
    high-water mark (current + staged reservation) stays <= budget, and
    staged windows are adopted instead of re-read."""
    g = random_graph(200, 5.0, seed=6)
    source = InMemorySource(g)
    plan = attach_halos(plan_partitions(_row_ptr(g), num_partitions=6),
                        lambda lo, hi: source.window("dst", lo, hi))
    budget = max(slice_nbytes(p) for p in plan.parts) * 2
    ledger = MemoryLedger(budget)
    loader = SliceLoader(source, plan, ledger, prefetch=True)
    for _sweep in range(2):
        for i in range(plan.num_partitions):
            loader.load(i)
            loader.prefetch((i + 1) % plan.num_partitions, keep=i)
    assert ledger.peak <= budget
    assert loader.prefetches > 0 and loader.prefetch_hits > 0
    loader.clear()                      # joins + releases staged windows
    assert ledger.current == 0


def test_halo_label_cache_epoch_invalidation():
    """A cached view is served byte-free while its rows are unchanged;
    after an owning partition rewrites a vertex (advance), only the
    stale rows are re-uploaded."""
    ledger = MemoryLedger(1 << 20)
    arr = (np.arange(100, dtype=np.int32) * 10).copy()
    cache = HaloLabelCache(ledger, n=100, n_loc=16, what="labels")
    ids = np.array([5, 7, 50, 99])
    v1 = np.asarray(cache.gather(0, ids, arr))
    assert np.array_equal(v1[:4], arr[ids]) and v1.shape == (16,)
    assert cache.hits == 0 and cache.bytes == 4 * arr.itemsize
    # unchanged revisit: a pure hit, zero bytes uploaded
    v2 = np.asarray(cache.gather(0, ids, arr))
    assert cache.hits == 1 and np.array_equal(v2, v1)
    assert cache.bytes == 4 * arr.itemsize
    # the owner of vertex 50 relabels it: exactly that entry refreshes
    arr[50] = -1
    changed = np.zeros(100, dtype=bool)
    changed[50] = True
    cache.advance(changed)
    v3 = np.asarray(cache.gather(0, ids, arr))
    assert v3[2] == -1
    assert np.array_equal(v3[[0, 1, 3]], v1[[0, 1, 3]])
    assert cache.hits == 1              # a refresh visit is not a hit
    assert cache.bytes == 5 * arr.itemsize          # 4 initial + 1 stale
    assert cache.bytes_saved == (4 + 3) * arr.itemsize
    cache.drop()
    assert ledger.current == 0


def test_halo_label_cache_respects_budget():
    """No room for even one entry -> gather declines (returns None) and
    the caller falls back to the plain host gather; spill frees LRU."""
    arr = np.arange(32, dtype=np.int32)
    tiny = HaloLabelCache(MemoryLedger(32), n=32, n_loc=16)  # entry = 64 B
    assert tiny.gather(0, np.array([1, 2]), arr) is None
    ledger = MemoryLedger(160)          # room for two 64 B entries
    cache = HaloLabelCache(ledger, n=32, n_loc=16)
    for idx in range(3):                # third insert evicts LRU entry 0
        assert cache.gather(idx, np.array([idx]), arr) is not None
    assert cache.stats()["entries"] == 2 and ledger.peak <= 160
    assert cache.spill(64) == 64        # window loads can reclaim room
    assert cache.stats()["entries"] == 1


def test_single_partition_too_big_raises():
    g = random_graph(100, 5.0, seed=7)
    source = InMemorySource(g)
    with pytest.raises(MemoryBudgetExceeded):
        fit_out_of_core(source, EngineConfig(backend="segment"),
                        memory_budget=64, num_partitions=2)


# --- bit-parity with the in-core engine ------------------------------------

@pytest.mark.parametrize("backend,fixtures", [
    ("segment", ("random", "communities")),
    ("tile", ("tile_mix",)),
])
@pytest.mark.parametrize("split", ["lp", "lpp", "none"])
def test_ooc_parity_backends_splits(backend, fixtures, split):
    cfg = EngineConfig(backend=backend, split=split)
    eng = Engine(cfg, cache=CompileCache())
    for name in fixtures:
        g = FIXTURES[name]()
        budget = _tight_budget(g, backend)
        ref = eng.fit(g)
        ooc = eng.fit(g, memory_budget=budget)
        assert ooc.partitions > 1, f"{name}: budget did not partition"
        assert np.array_equal(ref.labels, ooc.labels), \
            f"{name}: {backend}/{split} OOC labels diverge from in-core"
        assert ref.lpa_iterations == ooc.lpa_iterations
        assert ref.split_iterations == ooc.split_iterations
        assert ooc.ooc["peak_resident_bytes"] <= budget
        if split != "none":
            assert ooc.check_connected(g) == 0.0


def test_ooc_parity_shortcut_exact_weighted():
    g = random_graph(180, 4.0, seed=8)
    # beyond-paper shortcut: applied as a global pointer jump per sweep
    eng = Engine(EngineConfig(backend="segment", split="lpp",
                              shortcut=True), cache=CompileCache())
    assert np.array_equal(eng.fit(g).labels,
                          eng.fit(g, memory_budget=_tight_budget(g)).labels)
    # exact bucketing bakes the threshold with Python float semantics
    eng = Engine(EngineConfig(backend="segment", bucketing="exact"),
                 cache=CompileCache())
    assert np.array_equal(eng.fit(g).labels,
                          eng.fit(g, memory_budget=_tight_budget(g)).labels)
    # float32-exact weights keep the segment sums bit-stable
    rng = np.random.default_rng(9)
    e = rng.integers(0, 150, size=(400, 2))
    gw = build_graph(e, rng.choice([0.5, 1.0, 1.5, 2.0], size=400), n=150)
    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    assert np.array_equal(eng.fit(gw).labels,
                          eng.fit(gw, memory_budget=_tight_budget(gw)).labels)


def test_ooc_warm_start_parity():
    g = random_graph(200, 4.0, seed=10)
    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    base = eng.fit(g).labels
    frontier = np.zeros(g.n, bool)
    frontier[:40] = True
    ref = eng.fit(g, init_labels=base, init_active=frontier)
    ooc = eng.fit(g, init_labels=base, init_active=frontier,
                  memory_budget=_tight_budget(g))
    assert ref.warm_started and ooc.warm_started
    assert ooc.partitions > 1
    assert np.array_equal(ref.labels, ooc.labels)
    with pytest.raises(ValueError, match="init_labels"):
        eng.fit(g, init_labels=base[:-1], memory_budget=_tight_budget(g))


@pytest.mark.parametrize("split", ["lp", "lpp", "none"])
def test_ooc_segment_fused_parity(split):
    """Segment fused partition sweeps (one jitted dispatch per visit)
    are bit-identical to the unfused wake+move/wake+min pair."""
    g = random_graph(220, 4.0, seed=3)
    source = InMemorySource(g)
    budget = _tight_budget(g)
    runs = {}
    for fuse in ("on", "off"):
        cfg = EngineConfig(backend="segment", split=split, fuse_sweeps=fuse)
        runs[fuse] = fit_out_of_core(source, cfg, memory_budget=budget,
                                     cache=CompileCache())
    assert runs["on"].fused and not runs["off"].fused
    assert runs["on"].num_partitions > 1
    assert np.array_equal(runs["on"].labels, runs["off"].labels), split
    assert runs["on"].lpa_iterations == runs["off"].lpa_iterations
    assert runs["on"].split_iterations == runs["off"].split_iterations


def test_ooc_tile_fused_interpret_parity():
    """Tile fused partition sweeps under interpret mode (the real kernel
    body) against the in-core fit."""
    g = FIXTURES["tile_mix"]()
    eng = Engine(EngineConfig(backend="tile", kernel_mode="interpret",
                              fuse_sweeps="on"), cache=CompileCache())
    ref = eng.fit(g)
    ooc = eng.fit(g, memory_budget=_tight_budget(g, "tile"))
    assert ooc.partitions > 1
    assert np.array_equal(ref.labels, ooc.labels)
    assert ref.lpa_iterations == ooc.lpa_iterations
    assert ref.split_iterations == ooc.split_iterations


def test_ooc_prefetch_parity_and_budget():
    """Prefetch on vs off: same labels, same iteration counts, ledger
    peak (with the second window staged) still <= budget."""
    g = random_graph(220, 4.0, seed=3)
    source = InMemorySource(g)
    cfg = EngineConfig(backend="segment", split="lp")
    budget = _tight_budget(g)
    cache = CompileCache()
    base = fit_out_of_core(source, cfg, memory_budget=budget, cache=cache,
                           prefetch=False, halo_cache=False)
    # under this tight budget a second window cannot be reserved, so the
    # loader declines every stage — the run must still be exact
    pre = fit_out_of_core(source, cfg, memory_budget=budget, cache=cache,
                          prefetch=True, halo_cache=True)
    assert pre.num_partitions > 1
    assert np.array_equal(base.labels, pre.labels)
    assert base.lpa_iterations == pre.lpa_iterations
    assert base.split_iterations == pre.split_iterations
    assert pre.peak_resident_bytes <= budget
    assert base.peak_resident_bytes <= budget


def test_ooc_prefetch_and_halo_cache_engage():
    """With headroom over the windows, staged loads are adopted and the
    halo label cache serves revisits without re-gathering."""
    g = random_graph(220, 4.0, seed=3)
    source = InMemorySource(g)
    cfg = EngineConfig(backend="segment", split="lp")
    budget = 3 * in_core_edge_bytes(source)   # room for ~2 windows + caches
    run = fit_out_of_core(source, cfg, memory_budget=budget,
                          num_partitions=4, cache=CompileCache(),
                          prefetch=True, halo_cache=True)
    assert run.num_partitions == 4
    assert run.prefetches > 0 and run.prefetch_hits > 0
    assert run.halo_cache_hits > 0 and run.halo_cache_bytes_saved > 0
    assert run.peak_resident_bytes <= budget


# --- engine routing + guards -----------------------------------------------

def test_engine_routes_by_budget():
    g = random_graph(200, 4.0, seed=11)
    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    small = eng.fit(g, memory_budget=_tight_budget(g))
    assert small.partitions > 1 and small.ooc is not None
    big = eng.fit(g, memory_budget="1GB")
    assert big.partitions == 1 and big.ooc is None
    assert np.array_equal(small.labels, big.labels)
    # config-level budget applies without the per-call kwarg
    eng2 = Engine(EngineConfig(backend="segment",
                               memory_budget=_tight_budget(g)),
                  cache=CompileCache())
    assert eng2.fit(g).partitions > 1


def test_ooc_guards():
    g = random_graph(120, 4.0, seed=12)
    budget = _tight_budget(g)
    eng = Engine(EngineConfig(backend="segment", split="bfs_host"),
                 cache=CompileCache())
    with pytest.raises(ValueError, match="bfs_host"):
        eng.fit(g, memory_budget=budget)
    eng = Engine(EngineConfig(backend="segment", compute_metrics=True),
                 cache=CompileCache())
    with pytest.raises(ValueError, match="compute_metrics"):
        eng.fit(g, memory_budget=budget)
    eng = Engine(EngineConfig(backend="sharded"), cache=CompileCache())
    with pytest.raises(ValueError, match="partition"):
        eng.fit(g, memory_budget=budget)
    with pytest.raises(ValueError):
        EngineConfig(patch_churn_threshold=1.5)
    assert EngineConfig(memory_budget="64MB").memory_budget == 64_000_000


def test_ooc_sweeps_share_compiled_plans():
    """Every partition (and every later same-shape fit) reuses one
    executable per sweep stage — the compile cache keys on config, jax's
    jit cache on the uniform partition shapes."""
    from repro.engine.cache import TRACE_LOG
    g = random_graph(200, 4.0, seed=13)
    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    TRACE_LOG.reset()
    first = eng.fit(g, memory_budget=_tight_budget(g))
    traces = TRACE_LOG.total("segment:part_")
    assert first.partitions > 1
    eng.fit(g, memory_budget=_tight_budget(g))
    assert TRACE_LOG.total("segment:part_") == traces, \
        "second OOC fit re-traced the partition sweeps"


# --- store-backed path ------------------------------------------------------

def test_ooc_from_store_path(tmp_path, monkeypatch):
    from repro.io.formats import write_snap
    monkeypatch.setenv("REPRO_GRAPH_CACHE", str(tmp_path / "cache"))
    rng = np.random.default_rng(14)
    e = rng.integers(0, 300, size=(800, 2))
    path = tmp_path / "g.snap.txt"
    write_snap(path, e)

    eng = Engine(EngineConfig(backend="segment"), cache=CompileCache())
    ref = eng.fit(str(path))
    ooc = eng.fit(str(path), memory_budget="12KB")
    assert ooc.partitions > 1
    assert np.array_equal(ref.labels, ooc.labels)
    assert ooc.ooc["peak_resident_bytes"] <= parse_bytes("12KB")

    # the routing check for paths reads store metadata, not the arrays
    source = open_source(str(path))
    assert source.n == ref.labels.shape[0]
    assert in_core_edge_bytes(source) > parse_bytes("12KB")


def test_store_entry_windows_are_zero_copy(tmp_path, monkeypatch):
    from repro.io.formats import write_snap
    from repro.io.store import load_graph, open_graph
    monkeypatch.setenv("REPRO_GRAPH_CACHE", str(tmp_path / "cache"))
    rng = np.random.default_rng(15)
    e = rng.integers(0, 100, size=(250, 2))
    path = tmp_path / "g.snap.txt"
    write_snap(path, e)
    g = load_graph(str(path))
    handle = open_graph(str(path))
    assert handle.n == g.n and handle.num_edges == g.num_edges
    full_dst = np.asarray(g.dst)
    win = handle.window("dst", 10, 60)
    assert np.array_equal(win, full_dst[10:60])
    # zero-copy: the window is a view over the entry's mmap
    assert win.base is not None
    assert handle.fingerprint is not None


def test_ingest_cli_ooc(tmp_path, monkeypatch, capsys):
    from repro.io.formats import write_snap
    from repro.launch.ingest import main
    monkeypatch.setenv("REPRO_GRAPH_CACHE", str(tmp_path / "cache"))
    rng = np.random.default_rng(16)
    e = rng.integers(0, 200, size=(500, 2))
    path = tmp_path / "g.snap.txt"
    write_snap(path, e)
    out_json = tmp_path / "report.json"
    assert main([str(path), "--ooc", "--memory-budget", "16KB",
                 "--backend", "segment", "--cache-dir",
                 str(tmp_path / "cache"), "--json", str(out_json)]) == 0
    text = capsys.readouterr().out
    assert "ooc[segment]" in text and "partitions=" in text
    import json
    rep = json.loads(out_json.read_text())[0]
    assert rep["ooc"]["partitions"] > 1
    assert rep["ooc"]["peak_resident_bytes"] <= parse_bytes("16KB")
