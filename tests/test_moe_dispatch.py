"""MoE hierarchical dispatch: shard-local (dp>1) == global (dp=1) when no
tokens are dropped; capacity semantics and drop accounting."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod
from repro.models.common import init_from_specs

REPO = Path(__file__).resolve().parents[1]


def _params(d=64, ff=128, e=8, seed=0):
    specs = moe_mod.moe_specs(d, ff, e)
    return init_from_specs(specs, jax.random.PRNGKey(seed))


def test_every_kept_token_routed_to_topk():
    d, e, k = 64, 8, 2
    params = _params(d=d, e=e)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d)
                          ).astype(jnp.bfloat16)
    y = moe_mod.moe_apply(params, x, n_experts=e, n_experts_padded=e,
                          top_k=k, capacity_factor=8.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    # with huge capacity nothing drops: output must be non-zero everywhere
    mags = jnp.abs(y.astype(jnp.float32)).sum(-1)
    assert float((mags > 0).mean()) > 0.99


def test_padded_experts_never_selected():
    d, e_real, e_pad = 64, 5, 8
    params = _params(d=d, e=e_pad)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, d)
                          ).astype(jnp.bfloat16)
    # peek at routing internals: padded-expert logits masked to -inf
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    logits = jnp.where((jnp.arange(e_pad) >= e_real)[None, :], -1e30,
                       logits)
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    assert int(idx.max()) < e_real


_CHILD = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import moe as moe_mod
from repro.models.common import init_from_specs
from repro.parallel.api import MeshRules, use_rules

from repro.parallel.compat import make_mesh
mesh = make_mesh((8, 1), ("data", "model"))
d, e, k = 64, 8, 2
params = init_from_specs(moe_mod.moe_specs(d, 128, e), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (16, 8, d)).astype(jnp.bfloat16)

apply = lambda: moe_mod.moe_apply(params, x, n_experts=e, n_experts_padded=e,
                                  top_k=k, capacity_factor=16.0)
y_global = apply()                     # no rules -> dp=1 global dispatch
rules = MeshRules(mesh=mesh, mapping={"batch": ("data",), "expert": "model",
                                      "embed": None, "ff": "model"})
with use_rules(rules):
    y_local = jax.jit(lambda: apply())()   # dp=8 shard-local dispatch
err = float(jnp.max(jnp.abs(y_global.astype(jnp.float32)
                            - y_local.astype(jnp.float32))))
ref = float(jnp.max(jnp.abs(y_global.astype(jnp.float32)))) + 1e-9
print("RESULT" + json.dumps({"rel_err": err / ref}))
"""


def test_local_dispatch_matches_global():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    rel = json.loads(line[len("RESULT"):])["rel_err"]
    assert rel < 0.02, rel   # bf16 accumulation-order tolerance
