"""End-to-end GSL-LPA (Algorithm 3) — the paper's headline claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    disconnected_fraction,
    gsl_lpa,
    gve_lpa,
    modularity,
)
from repro.graphgen import (
    erdos_renyi,
    karate_club,
    planted_partition,
    ring_of_cliques,
    rmat,
)

GRAPHS = {
    "karate": lambda: karate_club()[0],
    "ring": lambda: ring_of_cliques(10, 5),
    "planted": lambda: planted_partition(8, 40, 0.3, 0.004, seed=2)[0],
    "er": lambda: erdos_renyi(400, 6.0, seed=4),
    "rmat": lambda: rmat(10, 8, seed=6),
}


@pytest.mark.parametrize("name", sorted(GRAPHS))
@pytest.mark.parametrize("split", ["lp", "lpp", "bfs_host"])
def test_gsl_never_disconnected(name, split):
    """Paper claim (Fig. 3c / 4d / 7d): zero disconnected communities."""
    g = GRAPHS[name]()
    res = gsl_lpa(g, split=split)
    frac = float(disconnected_fraction(g, jnp.asarray(res.labels)))
    assert frac == 0.0


@pytest.mark.parametrize("name", sorted(GRAPHS))
def test_split_never_lowers_modularity_much(name):
    """Paper claim (Fig. 3b / 7c): SL modularity >= default (within eps).

    Splitting a disconnected community can only increase sigma_c terms'
    balance; the paper reports +0.4% on average.
    """
    g = GRAPHS[name]()
    gve = gve_lpa(g)
    gsl = gsl_lpa(g, split="lp")
    q_gve = float(modularity(g, jnp.asarray(gve.labels)))
    q_gsl = float(modularity(g, jnp.asarray(gsl.labels)))
    assert q_gsl >= q_gve - 1e-6


def test_split_is_pure_refinement():
    from conftest import is_partition_refinement
    g = GRAPHS["rmat"]()
    gve = gve_lpa(g)
    gsl = gsl_lpa(g, split="lp")
    assert is_partition_refinement(gsl.labels, gve.labels)


def test_phase_timing_recorded():
    g = GRAPHS["planted"]()
    res = gsl_lpa(g, split="lp")
    assert res.lpa_seconds > 0 and res.split_seconds > 0
    assert res.lpa_iterations >= 1 and res.split_iterations >= 1


def test_gve_sometimes_disconnected_on_random_graphs():
    """The problem the paper fixes must actually occur (cf. 6.6% for
    GVE-LPA in §A.2): across seeds, default LPA yields at least one
    internally-disconnected community somewhere."""
    hits = 0
    for seed in range(12):
        g = erdos_renyi(150, 5.0, seed=seed)
        res = gve_lpa(g)
        if float(disconnected_fraction(g, jnp.asarray(res.labels))) > 0:
            hits += 1
    assert hits >= 1, "disconnection never occurred; test graphs too easy"


def test_gsl_result_carries_engine_detail():
    """The facade keeps Engine observability: the full DetectionResult
    rides along on ``.detail`` (timings, backend, cache_hit, bucket)."""
    import numpy as np
    g = GRAPHS["karate"]()
    res = gsl_lpa(g, split="lp")
    d = res.detail
    assert d is not None
    assert d.backend == "segment"
    assert isinstance(d.cache_hit, bool)
    assert set(d.timings) == {"prepare", "propagation", "split", "compact"}
    assert d.timings["propagation"] == res.lpa_seconds
    assert np.array_equal(d.labels, res.labels)
    assert d.num_communities == len(set(res.labels.tolist()))
