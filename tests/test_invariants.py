"""The paper's headline guarantee as an invariant suite: after GSL-LPA
with any splitting mode, *zero* communities are internally disconnected —
for every backend, solo and batched, on adversarial fixtures and (when
hypothesis is installed; marked ``slow``) on generated graphs.
"""
import numpy as np
import pytest

from repro.core import disconnected_communities_host
from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import (
    figure1_graph,
    grid2d,
    karate_club,
    planted_partition,
    ring_of_cliques,
)
from repro.core.graph import build_graph
from conftest import random_graph

BACKENDS = ("segment", "tile", "sharded")
SPLITS = ("lp", "lpp", "bfs_host")  # the modes that promise the invariant


def adversarial_fixtures():
    """Graphs engineered to provoke internally-disconnected communities:
    the paper's Figure 1 cut-vertex defection, bridge-of-cliques rings,
    low-degree lattices, disconnected + weighted random graphs, and an
    edgeless graph."""
    return {
        "figure1": figure1_graph()[0],
        "ring_of_cliques": ring_of_cliques(6, 5),
        "grid2d": grid2d(6),
        "karate": karate_club()[0],
        "disconnected_random": random_graph(64, 2.0, seed=13),
        "weighted_random": random_graph(48, 4.0, seed=17, weighted=True),
        "planted": planted_partition(4, 16, 0.4, 0.02, seed=5)[0],
        "edgeless": build_graph(np.zeros((0, 2), np.int64), n=11),
    }


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


def assert_connected(graph, result, ctx):
    """Invariant via the lazy helper + the host BFS oracle (Alg. 4)."""
    assert result.check_connected(graph) == 0.0, ctx
    flags = disconnected_communities_host(graph, result.labels)
    assert not any(flags.values()), (ctx, flags)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("split", SPLITS)
def test_no_disconnected_communities_fit(backend, split):
    if backend == "sharded" and split == "lpp":
        pytest.skip("sharded backend has no pruning split variant")
    eng = fresh_engine(backend=backend, split=split)
    for name, g in adversarial_fixtures().items():
        assert_connected(g, eng.fit(g), (backend, split, name))


@pytest.mark.parametrize("backend", ("segment", "tile"))
@pytest.mark.parametrize("split", SPLITS)
def test_no_disconnected_communities_fit_many(backend, split):
    graphs = list(adversarial_fixtures().values())
    eng = fresh_engine(backend=backend, split=split)
    results = eng.fit_many(graphs)
    for i, (name, g) in enumerate(adversarial_fixtures().items()):
        assert_connected(g, results[i], (backend, split, name))


def test_adversarial_warm_start_still_repairs():
    """Figure 1/2: warm-starting from the internally-disconnected
    assignment (vertex 3 defected to C2) must still come out clean —
    Split-Last runs regardless of where propagation started."""
    g, _before, after = figure1_graph()
    for backend in ("segment", "tile"):
        for split in SPLITS:
            eng = fresh_engine(backend=backend, split=split)
            res = eng.fit(g, init_labels=after)
            assert_connected(g, res, (backend, split))
            (res_b,) = eng.fit_many([g], init_labels=[after])
            assert np.array_equal(res_b.labels, res.labels)


def test_split_none_can_violate_the_invariant():
    """Sanity check that the suite can fail: plain LPA (split='none') on
    the Figure 1 graph, seeded from the defected assignment, keeps C1
    internally disconnected — exactly what Split-Last exists to fix."""
    g, _before, after = figure1_graph()
    res = fresh_engine(split="none").fit(g, init_labels=after)
    assert res.check_connected(g) > 0.0


# The hypothesis-generated half of this suite lives in
# tests/test_invariants_props.py (module-level importorskip must not
# take these deterministic fixtures down with it).
