"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels execute in interpret mode on CPU (the kernel *body* runs for real);
mode='pallas' on an actual TPU takes the identical code path.
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.graph import to_padded_neighbors
from repro.kernels import ops
from repro.kernels.ref import label_argmax_ref
from conftest import random_graph


def _tiles(n, d, seed, n_labels=None, wdtype=np.float32):
    rng = np.random.default_rng(seed)
    n_labels = n_labels or max(n // 2, 2)
    lab = rng.integers(0, n_labels, size=(n, d)).astype(np.int32)
    w = rng.uniform(0.1, 5.0, size=(n, d)).astype(wdtype)
    mask = rng.random((n, d)) < 0.8
    cur = rng.integers(0, n_labels, size=(n,)).astype(np.int32)
    return jnp.asarray(lab), jnp.asarray(w), jnp.asarray(mask), \
        jnp.asarray(cur)


@pytest.mark.parametrize("shape", [(8, 128), (16, 128), (8, 256),
                                   (40, 128), (64, 512), (128, 384)])
@pytest.mark.parametrize("seed", [0, 3])
def test_label_argmax_shape_sweep(shape, seed):
    lab, w, mask, cur = _tiles(*shape, seed=seed)
    for s in (0, 1, 12345):
        out_p = ops.label_argmax(lab, w, mask, cur, s, mode="interpret")
        out_r = ops.label_argmax(lab, w, mask, cur, s, mode="ref")
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


@pytest.mark.parametrize("shape", [(8, 128), (48, 256), (16, 640)])
def test_min_label_shape_sweep(shape, seed=1):
    n, d = shape
    rng = np.random.default_rng(seed)
    nbr_lab = jnp.asarray(rng.integers(0, n, (n, d)).astype(np.int32))
    nbr_comm = jnp.asarray(rng.integers(0, 4, (n, d)).astype(np.int32))
    mask = jnp.asarray(rng.random((n, d)) < 0.7)
    self_lab = jnp.arange(n, dtype=jnp.int32)
    self_comm = jnp.asarray(rng.integers(0, 4, (n,)).astype(np.int32))
    a = ops.min_label(nbr_lab, nbr_comm, mask, self_lab, self_comm,
                      mode="interpret")
    b = ops.min_label(nbr_lab, nbr_comm, mask, self_lab, self_comm,
                      mode="ref")
    assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4), st.integers(0, 99_999))
def test_label_argmax_property(nb, db, seed):
    """Random tiles: kernel == oracle == brute force."""
    n, d = nb * 8, db * 128
    lab, w, mask, cur = _tiles(n, d, seed)
    bl, bw, cw = (np.asarray(x) for x in
                  ops.label_argmax(lab, w, mask, cur, seed % 7,
                                   mode="interpret"))
    labn, wn, maskn, curn = (np.asarray(x) for x in (lab, w, mask, cur))
    for i in range(n):
        acc = {}
        for j in range(d):
            if maskn[i, j]:
                acc[labn[i, j]] = acc.get(labn[i, j], 0.0) + wn[i, j]
        if not acc:
            assert bw[i] == 0.0
            continue
        best = max(acc.values())
        np.testing.assert_allclose(bw[i], best, rtol=1e-5)
        assert labn[i][maskn[i]].tolist().count(bl[i]) > 0
        np.testing.assert_allclose(acc.get(bl[i], -1.0), best, rtol=1e-5)
        np.testing.assert_allclose(cw[i], acc.get(curn[i], 0.0), rtol=1e-5)


def test_kernels_on_real_graph_tiles():
    g = random_graph(60, 6.0, seed=11, weighted=True)
    nbr, nw, nmask = to_padded_neighbors(g)
    labels = jnp.arange(nbr.shape[0], dtype=jnp.int32)
    nbr_lab = labels[jnp.asarray(nbr)]
    a = ops.label_argmax(nbr_lab, jnp.asarray(nw), jnp.asarray(nmask),
                         labels, 0, mode="interpret")
    b = label_argmax_ref(nbr_lab, jnp.asarray(nw), jnp.asarray(nmask),
                         labels, jnp.int32(0))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_vmem_tile_budget():
    """ops.pick_tile_b must keep the equality cube within the VMEM budget."""
    for n_pad, d in [(1024, 128), (4096, 512), (65536, 1024), (40, 128)]:
        t = ops.pick_tile_b(n_pad, d)
        assert n_pad % t == 0
        assert t * d * d * 4 <= 4 * 1024 * 1024 or t == 1
