"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels execute in interpret mode on CPU (the kernel *body* runs for real);
mode='pallas' on an actual TPU takes the identical code path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:        # only the brute-force property test needs hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    given = settings = st = None

from repro.core.graph import to_padded_neighbors
from repro.kernels import ops
from repro.kernels.ref import label_argmax_ref
from conftest import random_graph


def _tiles(n, d, seed, n_labels=None, wdtype=np.float32):
    rng = np.random.default_rng(seed)
    n_labels = n_labels or max(n // 2, 2)
    lab = rng.integers(0, n_labels, size=(n, d)).astype(np.int32)
    w = rng.uniform(0.1, 5.0, size=(n, d)).astype(wdtype)
    mask = rng.random((n, d)) < 0.8
    cur = rng.integers(0, n_labels, size=(n,)).astype(np.int32)
    return jnp.asarray(lab), jnp.asarray(w), jnp.asarray(mask), \
        jnp.asarray(cur)


@pytest.mark.parametrize("shape", [(8, 128), (16, 128), (8, 256),
                                   (40, 128), (64, 512), (128, 384)])
@pytest.mark.parametrize("seed", [0, 3])
def test_label_argmax_shape_sweep(shape, seed):
    lab, w, mask, cur = _tiles(*shape, seed=seed)
    for s in (0, 1, 12345):
        out_p = ops.label_argmax(lab, w, mask, cur, s, mode="interpret")
        out_r = ops.label_argmax(lab, w, mask, cur, s, mode="ref")
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


@pytest.mark.parametrize("shape", [(8, 128), (48, 256), (16, 640)])
def test_min_label_shape_sweep(shape, seed=1):
    n, d = shape
    rng = np.random.default_rng(seed)
    nbr_lab = jnp.asarray(rng.integers(0, n, (n, d)).astype(np.int32))
    nbr_comm = jnp.asarray(rng.integers(0, 4, (n, d)).astype(np.int32))
    mask = jnp.asarray(rng.random((n, d)) < 0.7)
    self_lab = jnp.arange(n, dtype=jnp.int32)
    self_comm = jnp.asarray(rng.integers(0, 4, (n,)).astype(np.int32))
    a = ops.min_label(nbr_lab, nbr_comm, mask, self_lab, self_comm,
                      mode="interpret")
    b = ops.min_label(nbr_lab, nbr_comm, mask, self_lab, self_comm,
                      mode="ref")
    assert np.array_equal(np.asarray(a), np.asarray(b))


if st is not None:
    def _property_args(fn):
        return settings(max_examples=15, deadline=None)(
            given(st.integers(1, 6), st.integers(1, 4),
                  st.integers(0, 99_999))(fn))
else:
    _property_args = pytest.mark.skip(
        reason="property tests need hypothesis (requirements-dev.txt)")


@_property_args
def test_label_argmax_property(nb=2, db=1, seed=0):
    """Random tiles: kernel == oracle == brute force."""
    n, d = nb * 8, db * 128
    lab, w, mask, cur = _tiles(n, d, seed)
    bl, bw, cw = (np.asarray(x) for x in
                  ops.label_argmax(lab, w, mask, cur, seed % 7,
                                   mode="interpret"))
    labn, wn, maskn, curn = (np.asarray(x) for x in (lab, w, mask, cur))
    for i in range(n):
        acc = {}
        for j in range(d):
            if maskn[i, j]:
                acc[labn[i, j]] = acc.get(labn[i, j], 0.0) + wn[i, j]
        if not acc:
            assert bw[i] == 0.0
            continue
        best = max(acc.values())
        np.testing.assert_allclose(bw[i], best, rtol=1e-5)
        assert labn[i][maskn[i]].tolist().count(bl[i]) > 0
        np.testing.assert_allclose(acc.get(bl[i], -1.0), best, rtol=1e-5)
        np.testing.assert_allclose(cw[i], acc.get(curn[i], 0.0), rtol=1e-5)


def test_kernels_on_real_graph_tiles():
    g = random_graph(60, 6.0, seed=11, weighted=True)
    nbr, nw, nmask = to_padded_neighbors(g)
    labels = jnp.arange(nbr.shape[0], dtype=jnp.int32)
    nbr_lab = labels[jnp.asarray(nbr)]
    a = ops.label_argmax(nbr_lab, jnp.asarray(nw), jnp.asarray(nmask),
                         labels, 0, mode="interpret")
    b = label_argmax_ref(nbr_lab, jnp.asarray(nw), jnp.asarray(nmask),
                         labels, jnp.int32(0))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def _move_state(n, d, seed):
    """Wake/frontier state for the fused move kernel."""
    rng = np.random.default_rng(seed + 1000)
    chg = rng.random((n, d)) < 0.3
    active = rng.random(n) < 0.6
    cand_prev = rng.random(n) < 0.4
    klass = rng.random(n) < 0.7
    real = np.ones(n, dtype=bool)
    real[-max(n // 8, 1):] = False      # padded tail rows
    return tuple(jnp.asarray(x)
                 for x in (chg, active, cand_prev, klass, real))


@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (64, 512)])
@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("mode", ["interpret", "ref"])
def test_fused_move_matches_separate_dispatch(shape, seed, mode):
    """fused_move == wake glue + the separate label_argmax dispatch,
    bit-for-bit (labels AND the active frontier) in both kernel modes —
    including edgeless and self-loop rows the wake math must not
    resurrect, across tie-break seeds."""
    lab, w, mask, cur = _tiles(*shape, seed=seed)
    chg, active, cand_prev, klass, real = _move_state(*shape, seed)
    mask = mask.at[0].set(False)                      # edgeless row
    lab = lab.at[1].set(cur[1])                       # self-loop row
    for s in (0, 1, 12345):
        new, act = ops.fused_move(lab, w, mask, chg, cur, active,
                                  cand_prev, klass, real, s, mode=mode)
        wake = jnp.any(chg & mask, axis=1)
        act_sep = (active & ~cand_prev) | (wake & real)
        bl, bw, cw = ops.label_argmax(lab, w, mask, cur, s, mode=mode)
        adopt = (act_sep & klass) & (bw > jnp.maximum(cw, 0.0))
        new_sep = jnp.where(adopt, bl.astype(jnp.int32), cur)
        assert np.array_equal(np.asarray(new), np.asarray(new_sep)), \
            (shape, seed, mode, s)
        assert np.array_equal(np.asarray(act), np.asarray(act_sep)), \
            (shape, seed, mode, s)
        # edgeless row can never adopt; its frontier bit is wake-free
        assert int(new[0]) == int(cur[0])


@pytest.mark.parametrize("shape", [(8, 128), (48, 256)])
@pytest.mark.parametrize("prune", [True, False])
@pytest.mark.parametrize("mode", ["interpret", "ref"])
def test_fused_split_matches_separate_dispatch(shape, prune, mode):
    """fused_split == split-wake glue + the separate min_label dispatch,
    for both prune modes; chg=ones (the first-iteration trick) must
    reduce to the plain eager min_label sweep."""
    n, d = shape
    rng = np.random.default_rng(7)
    nbr_lab = jnp.asarray(rng.integers(0, n, (n, d)).astype(np.int32))
    nbr_comm = jnp.asarray(rng.integers(0, 4, (n, d)).astype(np.int32))
    mask = jnp.asarray(rng.random((n, d)) < 0.7).at[0].set(False)
    self_lab = jnp.arange(n, dtype=jnp.int32)
    self_comm = jnp.asarray(rng.integers(0, 4, (n,)).astype(np.int32))
    mres = ops.min_label(nbr_lab, nbr_comm, mask, self_lab, self_comm,
                         mode=mode)
    for chg_np in (np.ones((n, d), dtype=bool), rng.random((n, d)) < 0.4):
        chg = jnp.asarray(chg_np)
        out = ops.fused_split(nbr_lab, nbr_comm, mask, chg, self_lab,
                              self_comm, prune=prune, mode=mode)
        expect = mres
        if prune:
            same = mask & (nbr_comm == self_comm[:, None])
            wake = jnp.any(chg & same, axis=1)
            expect = jnp.where(wake, mres, self_lab)
        assert np.array_equal(np.asarray(out), np.asarray(expect)), \
            (shape, prune, mode, bool(chg_np.all()))
        if chg_np.all():
            # ones-trick: un-woken rows have no same-community neighbor,
            # where min_label already returns the row's own label
            assert np.array_equal(np.asarray(out), np.asarray(mres))


def test_vmem_tile_budget():
    """ops.pick_tile_b must keep the equality cube within the VMEM budget."""
    for n_pad, d in [(1024, 128), (4096, 512), (65536, 1024), (40, 128)]:
        t = ops.pick_tile_b(n_pad, d)
        assert n_pad % t == 0
        assert t * d * d * 4 <= 4 * 1024 * 1024 or t == 1
