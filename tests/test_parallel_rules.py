"""Sharding-rule unit tests (logical->physical mapping, ZeRO-1, caches)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.common import abstract_from_specs, logical_axes
from repro.parallel.rules import (
    cache_logical_axes,
    make_rules,
    param_shardings,
    zero1_shardings,
)


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: sharding-rule math without needing 4 real devices
    from repro.parallel.compat import abstract_mesh
    return abstract_mesh((2, 2), ("data", "model"))


def test_tp_axes_mapped(mesh):
    cfg = get_config("yi-9b")
    rules = make_rules(mesh, cfg, "train_4k")
    assert rules.spec(("embed", "ff")) == P(None, "model")
    assert rules.spec(("vocab", "embed")) == P("model")
    assert rules.spec(("embed", "heads", "head_dim")) == P(None, "model")


def test_axis_claimed_once(mesh):
    cfg = get_config("yi-9b")
    rules = make_rules(mesh, cfg, "train_4k")
    # two 'model'-mapped logical axes in one spec: second stays replicated
    assert rules.spec(("ff", "vocab")) == P("model")
    assert rules.spec(("heads", "ff", "embed")) == P("model")


def test_kv_heads_replicated_when_indivisible(mesh):
    cfg = get_config("yi-9b")          # kv=4, tp=2 here -> divisible
    rules = make_rules(mesh, cfg, "train_4k")
    assert rules.spec(("kv_heads",)) == P("model")
    from repro.parallel.compat import abstract_mesh
    big = abstract_mesh((1, 8), ("data", "model"))
    rules8 = make_rules(big, cfg, "train_4k")   # kv=4, tp=8 -> replicated
    assert rules8.spec(("kv_heads",)) == P()


def test_expert_axis_choice(mesh):
    # EP over 'data' with TP over 'ff' preferred (memory: dp x tp sharding)
    jam = get_config("jamba-v0.1-52b")
    assert make_rules(mesh, jam, "train_4k").mapping["expert"] == "data"
    arc = get_config("arctic-480b")
    assert make_rules(mesh, arc, "train_4k").mapping["expert"] == "data"


def test_long_context_sp(mesh):
    cfg = get_config("jamba-v0.1-52b")
    rules = make_rules(mesh, cfg, "long_500k")   # batch=1 < dp=2
    assert rules.mapping["batch"] is None
    assert rules.mapping["seq_kv"] == ("data",)
    r_train = make_rules(mesh, cfg, "train_4k")
    assert r_train.mapping["batch"] == ("data",)
    assert r_train.mapping["seq_kv"] is None


def test_zero1_claims_data_axis(mesh):
    cfg = get_config("yi-9b")
    rules = make_rules(mesh, cfg, "train_4k")
    specs = T.model_specs(cfg)
    axes = logical_axes(specs)
    ab = abstract_from_specs(specs)
    zsh = zero1_shardings(rules, axes, ab)
    # the embedding optimizer state must shard over data somewhere
    emb = zsh["embed"]["table"]
    flat = [a for s in emb.spec for a in
            (s if isinstance(s, tuple) else (s,)) if a]
    assert "data" in flat
    # and still be a valid sharding for the shape
    shape = ab["embed"]["table"].shape
    ndev_per_dim = []
    for dim, s in zip(shape, emb.spec):
        k = 1
        for a in (s if isinstance(s, tuple) else ((s,) if s else ())):
            k *= mesh.shape[a]
        assert dim % k == 0


def test_param_shardings_cover_tree(mesh):
    cfg = get_config("qwen2-moe-a2.7b")
    rules = make_rules(mesh, cfg, "train_4k")
    specs = T.model_specs(cfg)
    psh = param_shardings(rules, logical_axes(specs))
    n_params = len(jax.tree.leaves(abstract_from_specs(specs)))
    n_shardings = len(jax.tree.leaves(
        psh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_shardings


def test_cache_axes_heuristics(mesh):
    cfg = get_config("jamba-v0.1-52b")
    caches = T.init_decode_caches(cfg, batch=8, s_max=64, abstract=True)
    cax = cache_logical_axes(cfg, caches)
    leaves = jax.tree.leaves(cax, is_leaf=lambda x: isinstance(x, P))
    # must contain kv-cache specs and mamba state specs
    assert P("layers", "batch", "seq_kv", "kv_heads", "head_dim") in leaves
    assert P("layers", "batch", "ff", None) in leaves
