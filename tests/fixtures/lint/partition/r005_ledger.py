"""R005 positive fixture: edge-scale allocation with no ledger evidence."""
import numpy as np


def stage_edges(m_pad, dst):
    buf = np.zeros(m_pad, np.int32)  # EXPECT-R005
    buf[: len(dst)] = dst
    return buf
