"""R005 negative fixture: the same allocation, ledger-accounted."""
import numpy as np


def stage_edges(ledger, m_pad, dst):
    nbytes = m_pad * 4
    ledger.acquire(nbytes)
    buf = np.zeros(m_pad, np.int32)
    buf[: len(dst)] = dst
    return buf
