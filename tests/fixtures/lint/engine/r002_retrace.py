"""R002 positive fixture: ad-hoc jit in a non-compile-owning module and
a stringified compile-cache key."""
import jax


def compile_step(fn, bucket, cache):
    step = jax.jit(fn)  # EXPECT-R002
    key = f"plan-{bucket.n}-{bucket.m}"
    plan, hit = cache.get_or_build(key, lambda: step)  # EXPECT-R002
    return plan, hit
