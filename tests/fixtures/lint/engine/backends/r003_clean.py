"""R003 negative fixture: a minimal solo-only backend, fully conformant."""
from repro.engine.registry import register_backend


@register_backend("fixture-solo")
class SoloBackend:
    name = "fixture-solo"
    supports_batch = False

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, n_real, init_labels, init_active=None):
        return None
