"""R003 negative fixture: a minimal solo-only backend, fully conformant."""
from repro.engine.registry import register_backend


@register_backend("fixture-solo")
class SoloBackend:
    name = "fixture-solo"
    supports_batch = False

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, n_real, init_labels, init_active=None):
        return None


@register_backend("fixture-fused-ok")
class FusedBackend:
    """Partition + fused surface with reference parameter names."""
    name = "fixture-fused-ok"
    supports_batch = False
    supports_partition = True
    supports_fused_partition = True

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, n_real, init_labels, init_active=None):
        return None

    def build_partition(self, config):
        return object()

    def partition_caps(self, budget, d_bucket):
        return budget, None

    def partition_prepare_nbytes(self, shapes):
        return 0

    def prepare_partition(self, resident, shapes, config):
        return resident

    def partition_move(self, ops_ns, inputs, labels_loc, cand_owned,
                       seed, bound):
        return None

    def partition_wake(self, ops_ns, inputs, changed_loc):
        return None

    def partition_split(self, ops_ns, inputs, comm_loc, labels_loc,
                        active_owned, bound):
        return None

    def partition_split_wake(self, ops_ns, inputs, comm_loc, changed_loc):
        return None

    def partition_move_fused(self, ops_ns, inputs, labels_loc, changed_loc,
                             active_owned, cand_prev_owned, klass_owned,
                             seed, bound):
        return None

    def partition_split_fused(self, ops_ns, inputs, comm_loc, labels_loc,
                              changed_loc, bound):
        return None
