"""R006 positive fixture: telemetry inside jitted / per-sweep code.

Never imported — the lint tests feed this file's *source* through the
analyzer and assert the EXPECT-marked lines are flagged.
"""
import time

import jax


@jax.jit
def traced_with_timer(labels, active):
    t0 = time.perf_counter()  # EXPECT-R006
    return labels.sum() + active.sum() + t0


@jax.jit
def traced_with_metric(labels, counter):
    counter.inc()  # EXPECT-R006
    return labels.sum()


def run_with_per_sweep_timing(plan, graph, labels, active):
    it = 0
    while it < 10:
        t0 = time.perf_counter()  # EXPECT-R006
        labels, active, dn = plan.step(graph, labels, active)
        sweep_seconds = time.perf_counter() - t0  # EXPECT-R006
        it += 1
    return labels, sweep_seconds


def run_with_per_sweep_span(plan, graph, labels, active, span):
    for it in range(10):
        with span("sweep", it=it):  # EXPECT-R006
            labels, active, dn = plan.step(graph, labels, active)
    return labels


@jax.jit
def traced_with_quality(labels, graph, compute_quality):
    report = compute_quality(labels, mode="basic", graph=graph)  # EXPECT-R006
    return labels.sum(), report


def run_with_per_sweep_quality(plan, graph, labels, active, result):
    it = 0
    while it < 10:
        labels, active, dn = plan.step(graph, labels, active)
        result.check_connected(graph)  # EXPECT-R006
        it += 1
    return labels


def run_with_per_sweep_churn(plan, graph, labels, active, prev):
    from repro.obs.quality import label_churn
    for it in range(10):
        labels, active, dn = plan.step(graph, labels, active)
        churn, k = label_churn(prev, labels)  # EXPECT-R006
        prev = labels
    return labels, churn, k
