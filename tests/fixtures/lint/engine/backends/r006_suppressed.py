"""R006 suppression fixture: a justified per-sweep timer."""
import time


def run_debug_timing(plan, graph, labels, active):
    it = 0
    while it < 10:
        # lint: telemetry-ok — opt-in debug mode, off by default
        t0 = time.perf_counter()
        labels, active, dn = plan.step(graph, labels, active)
        it += 1
    return labels, t0
