"""R003 positive fixture: a registered backend that claims batch support
but ships no batch trio, with one drifted solo signature."""
from repro.engine.registry import register_backend


@register_backend("fixture-broken")
class BrokenBackend:  # EXPECT-R003
    name = "fixture-broken"
    supports_batch = True

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, num_real, init_labels, init_active):  # EXPECT-R003
        return None


@register_backend("fixture-fused")
class FusedWithoutPartition:  # EXPECT-R003
    """Claims the fused pair without the partition surface beneath it,
    and drifts one fused hook's parameter names."""
    name = "fixture-fused"
    supports_batch = False
    supports_fused_partition = True   # missing partition_split_fused too

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, n_real, init_labels, init_active=None):
        return None

    def partition_move_fused(self, ops_ns, inputs, labels, changed,  # EXPECT-R003
                             active_owned, cand_prev_owned, klass_owned,
                             seed, bound):
        return None
