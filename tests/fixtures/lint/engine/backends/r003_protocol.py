"""R003 positive fixture: a registered backend that claims batch support
but ships no batch trio, with one drifted solo signature."""
from repro.engine.registry import register_backend


@register_backend("fixture-broken")
class BrokenBackend:  # EXPECT-R003
    name = "fixture-broken"
    supports_batch = True

    def plan_key(self, config):
        return ()

    def build(self, bucket, config):
        return object()

    def prepare(self, graph, bucket, config):
        return graph

    def run(self, plan, inputs, num_real, init_labels, init_active):  # EXPECT-R003
        return None
