"""R006 negative fixture: legal stage-boundary telemetry.

Timing *around* the sweep loop, jax ``.at[...].set`` in traced code, and
telemetry in plain host helpers are all fine.
"""
import time

import jax
import jax.numpy as jnp


@jax.jit
def traced_profile_buffer(labels, buf, row):
    # device-side profile write: .set is the jax update idiom, not a gauge
    buf = buf.at[row].set(labels.sum())
    return labels, buf


def run_stage_boundary_timing(plan, graph, labels, active):
    t0 = time.perf_counter()
    it = 0
    while it < 10:
        labels, active, dn = plan.step(graph, labels, active)
        it += 1
    lpa_seconds = time.perf_counter() - t0
    return labels, lpa_seconds


def host_helper_metrics(counter, values):
    # no sweep dispatch in this loop: plain host bookkeeping is legal
    for v in values:
        counter.inc()
    return jnp.asarray(values)


def run_quality_at_stage_boundary(plan, graph, labels, active,
                                  compute_quality, record_report, scope):
    # quality hooks *after* the sweep loop converges are the contract:
    # one device pass over the final labels, at the engine's sync point
    it = 0
    while it < 10:
        labels, active, dn = plan.step(graph, labels, active)
        it += 1
    report = compute_quality(labels, mode="basic", graph=graph)
    record_report(scope, report)
    return labels, report
