"""R002 negative fixture: structured tuple cache keys are the contract."""


def fetch_plan(cache, name, bucket, cfg, builder):
    key = (name, tuple(bucket), cfg.algo_key())
    plan, hit = cache.get_or_build(key, builder)
    return plan, hit
