"""R004 positive fixture: pallas_call with no divisibility guard, an
oversized literal block footprint, and a host op in the kernel body."""
import numpy as np
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = np.asarray(x_ref[...])  # EXPECT-R004


def launch(x):
    return pl.pallas_call(  # EXPECT-R004
        _kernel,
        grid=(8,),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        out_shape=None,
    )(x)
