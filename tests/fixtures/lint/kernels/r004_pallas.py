"""R004 positive fixture: pallas_call with no divisibility guard, an
oversized literal block footprint, and a host op in the kernel body."""
import numpy as np
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = np.asarray(x_ref[...])  # EXPECT-R004


def launch(x):
    return pl.pallas_call(  # EXPECT-R004
        _kernel,
        grid=(8,),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        out_shape=None,
    )(x)


def _cube_kernel(lab_ref, w_ref, o_ref):
    lab = lab_ref[...]
    eq = (lab[:, :, None] == lab[:, None, :]).astype(w_ref[...].dtype)
    o_ref[...] = eq.sum(axis=2)


def launch_cube(lab, w, n_pad, tile_b):
    # guarded grid, but no cube-budget assert: the (B, D, D) cube is
    # invisible to the BlockSpec footprint check
    assert n_pad % tile_b == 0
    return pl.pallas_call(  # EXPECT-R004
        _cube_kernel,
        grid=(n_pad // tile_b,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0)),
                  pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=None,
    )(lab, w)
