"""R004 negative fixture: guarded grid, pure kernel body, small blocks."""
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def launch(x, n_pad, tile_b):
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    grid = (n_pad // tile_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=None,
    )(x)


CUBE_BUDGET = 4 * 1024 * 1024


def _cube_kernel(lab_ref, o_ref):
    lab = lab_ref[...]
    eq = (lab[:, :, None] == lab[:, None, :]).astype("float32")
    o_ref[...] = eq.sum(axis=2)


def launch_cube(lab, n_pad, d, tile_b):
    assert n_pad % tile_b == 0, (n_pad, tile_b)
    assert tile_b * d * d * 4 <= CUBE_BUDGET, (tile_b, d)
    return pl.pallas_call(
        _cube_kernel,
        grid=(n_pad // tile_b,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=None,
    )(lab)
