"""R001 suppression fixture: the hazard is real but justified inline —
the linter must report it as suppressed, not active."""


def drive(plan, graph, labels, active):
    while True:
        labels, active, dn = plan.step(graph, labels, active)
        # lint: host-sync-ok — fixture: justified convergence readback
        if int(dn) == 0:
            break
    return labels
