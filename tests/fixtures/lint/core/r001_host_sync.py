"""R001 positive fixture: host syncs on traced / device values.

Never imported — the lint tests feed this file's *source* through the
analyzer and assert the EXPECT-marked lines are flagged.
"""
import jax


@jax.jit
def traced_scalarize(labels, n_real):
    return labels.sum() + int(n_real)  # EXPECT-R001


def host_driven_sweeps(plan, graph, labels, active):
    it = 0
    while it < 10:
        labels, active, dn = plan.step(graph, labels, active)
        it += 1
        if int(dn) == 0:  # EXPECT-R001
            break
    return labels
