"""R001 negative fixture: host-side int()/np.asarray with no device
taint must stay clean (the rule is taint-based, not keyword-based)."""
import numpy as np


def host_prep(windows):
    counts = []
    for lo, hi in windows:
        counts.append(int(hi - lo))
    return np.asarray(counts)


def scalar_config(tau, n):
    threshold = int(np.float32(tau) * np.float32(n))
    return threshold
