"""Disconnected-community detection (paper Appendix A.1, Algorithm 4)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import disconnected_communities, disconnected_communities_host
from repro.graphgen import figure1_graph
from conftest import random_graph

pytestmark = pytest.mark.slow  # hypothesis suites ride the slow CI job


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 50), st.integers(0, 10_000), st.integers(1, 6))
def test_detect_matches_host_oracle(n, seed, n_comm):
    g = random_graph(n, 3.0, seed=seed)
    rng = np.random.default_rng(seed + 7)
    # community labels are vertex-id-valued in [0, n) (LPA invariant)
    comm = rng.integers(0, min(n_comm, n), size=n).astype(np.int32)
    flags, bad, total = disconnected_communities(g, jnp.asarray(comm))
    flags = np.asarray(flags)
    oracle = disconnected_communities_host(g, comm)
    assert int(total) == len(oracle)
    for c, is_bad in oracle.items():
        assert bool(flags[c]) == is_bad, (c, is_bad)
    assert int(bad) == sum(oracle.values())


def test_detect_figure1():
    g, _, after = figure1_graph()
    flags, bad, total = disconnected_communities(g, jnp.asarray(after))
    assert (int(bad), int(total)) == (1, 2)


def test_all_singletons_connected():
    g = random_graph(20, 3.0, seed=5)
    comm = jnp.arange(20, dtype=jnp.int32)
    _, bad, total = disconnected_communities(g, comm)
    assert int(bad) == 0 and int(total) == 20
