"""Data pipeline: determinism, state restore, host sharding, clustering."""
import numpy as np

from repro.data import SyntheticLMDataset
from repro.data.clustering import cluster_documents, locality_batches


def test_deterministic_replay():
    a = SyntheticLMDataset(vocab=1024, seq_len=32, global_batch=4, seed=7)
    b1 = [a.next_batch() for _ in range(3)]
    state = a.state()
    b2 = [a.next_batch() for _ in range(2)]
    a.restore(state)
    b3 = [a.next_batch() for _ in range(2)]
    for x, y in zip(b2, b3):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # restart from scratch replays everything
    c = SyntheticLMDataset(vocab=1024, seq_len=32, global_batch=4, seed=7)
    np.testing.assert_array_equal(b1[0]["tokens"],
                                  c.next_batch()["tokens"])


def test_targets_are_shifted_tokens():
    d = SyntheticLMDataset(vocab=512, seq_len=16, global_batch=2, seed=1)
    b = d.next_batch()
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_sharding_disjoint():
    """Two hosts of the same job draw disjoint rows that tile the global
    batch exactly as a single host would."""
    solo = SyntheticLMDataset(vocab=512, seq_len=8, global_batch=4, seed=3)
    h0 = SyntheticLMDataset(vocab=512, seq_len=8, global_batch=4, seed=3,
                            host_index=0, host_count=2)
    h1 = SyntheticLMDataset(vocab=512, seq_len=8, global_batch=4, seed=3,
                            host_index=1, host_count=2)
    whole = solo.next_batch()["tokens"]
    top = h0.next_batch()["tokens"]
    bot = h1.next_batch()["tokens"]
    np.testing.assert_array_equal(whole, np.concatenate([top, bot], 0))


def test_clustering_recovers_topics():
    """Docs drawn from k disjoint vocab blocks -> k clean communities."""
    rng = np.random.default_rng(0)
    k, per, seq, vocab = 4, 6, 64, 4096
    docs = np.zeros((k * per, seq), dtype=np.int64)
    for t in range(k):
        lo = t * (vocab // k)
        for i in range(per):
            docs[t * per + i] = rng.integers(lo, lo + vocab // k, size=seq)
    labels = cluster_documents(docs)
    for t in range(k):
        block = labels[t * per:(t + 1) * per]
        assert len(set(block.tolist())) == 1, labels
    assert len(set(labels.tolist())) == k
    batches = locality_batches(docs, per)
    assert sum(len(b) for b in batches) == k * per
