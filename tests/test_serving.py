"""Serving correctness: prefill + decode must agree with the train-mode
forward on the same token prefix (teacher-forcing consistency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import transformer as T
from repro.models.common import init_from_specs

# bf16 models: batched (train) vs step-by-step (decode) paths accumulate
# differently; MoE dispatch ordering adds a little more
TOL = 0.02


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_train_forward(arch):
    cfg = reduced_config(arch)
    params = init_from_specs(T.model_specs(cfg), jax.random.PRNGKey(1))
    b, s = 2, 24
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))
    batch = {"tokens": toks}
    pre = {"tokens": toks[:, : s - 1]}
    if cfg.family == "vlm":
        ve = jnp.asarray(rng.normal(size=(b, cfg.frontend_len, cfg.d_model)),
                         jnp.bfloat16)
        batch["vision_embeds"] = ve
        pre["vision_embeds"] = ve
    if cfg.kind == "encdec":
        fr = jnp.asarray(rng.normal(size=(b, 16, cfg.d_model)), jnp.bfloat16)
        batch["frames"] = fr
        pre["frames"] = fr

    full = T.forward_train(cfg, params, batch).astype(jnp.float32)
    logits_pre, caches = T.prefill(cfg, params, pre, s_max=64)
    dec, _ = T.decode_step(cfg, params, caches,
                           {"tokens": toks[:, s - 1: s]})

    offset = cfg.frontend_len if cfg.family == "vlm" else 0
    a = np.asarray(full[:, -1, : cfg.vocab])
    b_ = np.asarray(dec[:, -1, : cfg.vocab].astype(jnp.float32))
    rel = np.max(np.abs(a - b_)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < TOL, f"decode vs train: {rel}"

    c = np.asarray(full[:, offset + s - 2, : cfg.vocab])
    d = np.asarray(logits_pre[:, : cfg.vocab].astype(jnp.float32))
    rel2 = np.max(np.abs(c - d)) / (np.max(np.abs(c)) + 1e-9)
    assert rel2 < TOL, f"prefill vs train: {rel2}"


@pytest.mark.parametrize("arch", ["yi-9b", "jamba-v0.1-52b", "rwkv6-7b"])
def test_multi_step_decode_consistency(arch):
    """Decoding tokens one by one == train forward over the whole sequence."""
    cfg = reduced_config(arch)
    params = init_from_specs(T.model_specs(cfg), jax.random.PRNGKey(3))
    b, s_pre, n_dec = 1, 8, 6
    rng = np.random.default_rng(4)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s_pre + n_dec)).astype(np.int32))
    full = T.forward_train(cfg, params, {"tokens": toks}
                           ).astype(jnp.float32)
    _, caches = T.prefill(cfg, params, {"tokens": toks[:, :s_pre]},
                          s_max=64)
    for t in range(n_dec):
        dec, caches = T.decode_step(
            cfg, params, caches, {"tokens": toks[:, s_pre + t: s_pre + t + 1]})
        a = np.asarray(full[:, s_pre + t, : cfg.vocab])
        b_ = np.asarray(dec[:, -1, : cfg.vocab].astype(jnp.float32))
        rel = np.max(np.abs(a - b_)) / (np.max(np.abs(a)) + 1e-9)
        assert rel < TOL, (t, rel)


def test_serve_driver_runs():
    from repro.launch.serve import serve
    out = serve("qwen2-moe-a2.7b", batch=2, prompt_len=8, max_new=4,
                s_max=32)
    assert out["generated"].shape == (2, 4)
