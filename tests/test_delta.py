"""GraphDelta / apply_delta / affected_frontier: the streaming delta API."""
import numpy as np
import pytest

from repro.core import (
    GraphDelta,
    affected_frontier,
    apply_delta,
    graph_fingerprint,
    undirected_edges,
)
from repro.core.graph import build_graph, to_numpy_adj
from repro.graphgen import erdos_renyi, evolving_sequence, karate_club


def adj_dict(graph):
    """{(u, v): w} over u < v undirected edges (host oracle view)."""
    out = {}
    for u, nbrs in enumerate(to_numpy_adj(graph)):
        for v, w in nbrs:
            if u < v:
                out[(u, v)] = w
    return out


def test_make_canonicalises_and_defaults():
    d = GraphDelta.make(insert=[[5, 2], [3, 3], [1, 4]],
                        delete=[[7, 0]])
    # self loop dropped, endpoints ordered, unit default weights
    assert d.insertions.tolist() == [[2, 5], [1, 4]]
    assert d.insert_weights.tolist() == [1.0, 1.0]
    assert d.deletions.tolist() == [[0, 7]]
    assert d.touched_vertices().tolist() == [0, 1, 2, 4, 5, 7]
    assert not d.is_empty()
    assert GraphDelta.make().is_empty()
    with pytest.raises(ValueError):
        GraphDelta.make(insert=[[0, 1], [1, 2]], weights=[1.0])
    with pytest.raises(ValueError):
        GraphDelta.make(insert=[[-1, 2]])


def test_apply_delta_insert_delete_roundtrip():
    g = build_graph(np.array([[0, 1], [1, 2], [2, 3], [3, 0]]), n=5)
    d = GraphDelta.make(insert=[[0, 2], [1, 4]], delete=[[2, 3]])
    g2 = apply_delta(g, d)
    assert g2.n == 5
    assert adj_dict(g2) == {(0, 1): 1.0, (1, 2): 1.0, (0, 3): 1.0,
                            (0, 2): 1.0, (1, 4): 1.0}
    # the original graph is untouched (immutable pytree)
    assert adj_dict(g) == {(0, 1): 1.0, (1, 2): 1.0, (2, 3): 1.0,
                           (0, 3): 1.0}


def test_apply_delta_weight_semantics():
    g = build_graph(np.array([[0, 1], [1, 2]]),
                    np.array([2.0, 3.0], np.float32), n=3)
    # inserting an existing edge merges weights by summation
    g2 = apply_delta(g, GraphDelta.make(insert=[[1, 0]], weights=[0.5]))
    assert adj_dict(g2) == {(0, 1): 2.5, (1, 2): 3.0}
    # deleting removes the edge entirely, whatever its weight;
    # deleting a non-existent edge is a silent no-op
    g3 = apply_delta(g, GraphDelta.make(delete=[[0, 1], [0, 2]]))
    assert adj_dict(g3) == {(1, 2): 3.0}


def test_delete_with_out_of_range_endpoint_is_a_true_noop():
    """Regression: (2, 25) on a 10-vertex graph keys to 2*10+25 == 45 ==
    the key of real edge (4, 5) — the collision must not delete it."""
    g = build_graph(np.array([[0, 1], [4, 5]]), n=10)
    g2 = apply_delta(g, GraphDelta.make(delete=[[2, 25]]))
    assert adj_dict(g2) == {(0, 1): 1.0, (4, 5): 1.0}


def test_apply_delta_grows_but_never_shrinks():
    g = build_graph(np.array([[0, 1]]), n=2)
    g2 = apply_delta(g, GraphDelta.make(insert=[[1, 4]]))
    assert g2.n == 5  # endpoint beyond range grows the vertex set
    g3 = apply_delta(g, GraphDelta.make(num_vertices=6))
    assert g3.n == 6 and adj_dict(g3) == {(0, 1): 1.0}
    with pytest.raises(ValueError):
        apply_delta(g2, GraphDelta.make(num_vertices=3))


def test_empty_delta_preserves_fingerprint():
    g, _ = karate_club()
    assert graph_fingerprint(apply_delta(g, GraphDelta.make())) \
        == graph_fingerprint(g)


def test_undirected_edges_halves_directed():
    g = erdos_renyi(60, 4.0, seed=3)
    edges, wgt = undirected_edges(g)
    assert 2 * len(edges) == g.num_edges
    assert np.all(edges[:, 0] < edges[:, 1])
    assert len(wgt) == len(edges)


def test_affected_frontier_marks_endpoints_only():
    d = GraphDelta.make(insert=[[0, 3]], delete=[[2, 5]])
    f = affected_frontier(d, 8)
    assert f.tolist() == [True, False, True, True, False, True, False, False]
    assert not affected_frontier(GraphDelta.make(), 4).any()


def test_evolving_sequence_is_consistent_and_deterministic():
    base, deltas = evolving_sequence(80, 4.0, rounds=4, delta_edges=3, seed=7)
    base2, deltas2 = evolving_sequence(80, 4.0, rounds=4, delta_edges=3,
                                       seed=7)
    assert graph_fingerprint(base) == graph_fingerprint(base2)
    g, g2 = base, base2
    for d, d2 in zip(deltas, deltas2):
        assert d.num_insertions == 3 and d.num_deletions == 3
        # deletions target live edges, insertions are genuinely new
        live = set(map(tuple, undirected_edges(g)[0].tolist()))
        assert all(tuple(e) in live for e in d.deletions.tolist())
        assert all(tuple(e) not in live for e in d.insertions.tolist())
        g = apply_delta(g, d)
        g2 = apply_delta(g2, d2)
        assert graph_fingerprint(g) == graph_fingerprint(g2)
    assert g.num_edges == base.num_edges  # equal churn in and out
