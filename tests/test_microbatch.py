"""Micro-batching scheduler: batch formation, result parity, serving driver."""
import numpy as np
import pytest

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi
from repro.launch.microbatch import MicroBatcher


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


def test_batches_form_and_results_match_solo_fits():
    graphs = [erdos_renyi(n, 4.0, seed=i)
              for i, n in enumerate((60, 80, 60, 90, 70))]
    eng = fresh_engine(backend="segment")
    mb = MicroBatcher(eng, max_batch=2, batch_timeout_ms=50, autostart=False)
    subs = [mb.submit(g) for g in graphs]
    mb.start()
    results = [s.result(timeout=300) for s in subs]
    mb.close()

    # deterministic drain of a pre-enqueued burst: ceil-chunks of max_batch
    assert mb.batch_sizes == [2, 2, 1]
    assert [s.batch_size for s in subs] == [2, 2, 2, 2, 1]
    assert all(s.latency_s is not None and s.latency_s > 0 for s in subs)
    ref = fresh_engine(backend="segment")
    for g, r in zip(graphs, results):
        assert np.array_equal(r.labels, ref.fit(g).labels)

    stats = mb.stats()
    assert stats["requests"] == 5 and stats["batches"] == 3
    assert stats["batch_size_hist"] == {1: 1, 2: 2}
    assert stats["p95_ms"] >= stats["p50_ms"] > 0


def test_submit_after_close_raises_and_close_is_idempotent():
    mb = MicroBatcher(fresh_engine(), max_batch=4, autostart=False)
    mb.close()
    mb.close()
    with pytest.raises(RuntimeError):
        mb.submit(erdos_renyi(20, 3.0, seed=0))


def test_worker_exception_propagates_to_waiters():
    class Boom:
        def fit_many(self, graphs, backend=None):
            raise RuntimeError("boom")

    mb = MicroBatcher(Boom(), max_batch=2, autostart=False)
    sub = mb.submit(erdos_renyi(20, 3.0, seed=0))
    mb.start()
    mb.close()
    with pytest.raises(RuntimeError, match="boom"):
        sub.result(timeout=30)


def test_worker_crash_outside_dispatch_strands_nothing(monkeypatch):
    """Regression: a crash in the queue loop itself (outside _dispatch's
    protected engine call) used to exit the worker silently — every
    pending Submission.result() blocked forever and later submits
    enqueued into a dead worker.  Now the in-flight batch and all queued
    futures get the exception, and subsequent submit() raises."""
    mb = MicroBatcher(fresh_engine(), max_batch=2, batch_timeout_ms=0,
                      autostart=False)
    monkeypatch.setattr(MicroBatcher, "_dispatch",
                        lambda self, batch: (_ for _ in ()).throw(
                            RuntimeError("loop crash")))
    subs = [mb.submit(erdos_renyi(20, 3.0, seed=i)) for i in range(5)]
    mb.start()
    mb._thread.join(timeout=60)
    assert not mb._thread.is_alive()
    for s in subs:   # in-flight batch members AND still-queued submissions
        with pytest.raises(RuntimeError, match="loop crash"):
            s.result(timeout=30)
    with pytest.raises(RuntimeError, match="worker died"):
        mb.submit(erdos_renyi(20, 3.0, seed=9))
    mb.close()   # still clean: idempotent, no hang


def test_done_callback_fires_on_result_and_exception():
    """add_done_callback is the serving tier's async-settle hook."""
    import threading
    seen, ev = [], threading.Event()
    eng = fresh_engine(backend="segment")
    with MicroBatcher(eng, max_batch=2, batch_timeout_ms=5) as mb:
        sub = mb.submit(erdos_renyi(30, 3.0, seed=0))
        sub.add_done_callback(lambda s: (seen.append(s), ev.set()))
        assert ev.wait(timeout=60)
    assert seen == [sub] and sub.done() and sub.exception() is None

    class Boom:
        def fit_many(self, graphs, backend=None):
            raise ValueError("nope")

    ev2 = threading.Event()
    got: list = []
    with MicroBatcher(Boom(), max_batch=2) as mb:
        sub = mb.submit(erdos_renyi(20, 3.0, seed=1))
        sub.add_done_callback(lambda s: (got.append(s.exception()),
                                         ev2.set()))
        assert ev2.wait(timeout=60)
    assert isinstance(got[0], ValueError)


def test_mixed_warm_cold_batch_with_frontier_only_members():
    """Batches mixing members that carry init_active but no init_labels —
    the warm-cache auto path resolves their labels (or drops the frontier
    on a miss) — stay bit-identical to solo fits, member by member."""
    from repro.core import GraphDelta, affected_frontier, apply_delta

    graphs = [erdos_renyi(n, 4.0, seed=i)
              for i, n in enumerate((70, 85, 60))]
    eng = fresh_engine(backend="segment", warm_start="auto")
    oracle = fresh_engine(backend="segment", warm_start="auto")
    # populate both warm caches with the base structures
    for g in graphs:
        eng.fit(g)
        oracle.fit(g)

    deltas = [GraphDelta.make(insert=[[0, i + 2], [1, i + 3]])
              for i in range(3)]
    posts = [apply_delta(g, d) for g, d in zip(graphs, deltas)]
    fronts = [affected_frontier(d, g.n) for d, g in zip(deltas, posts)]
    # make posts[1]'s structure warm-cached so its frontier-only member
    # resolves labels from the cache inside the batch
    eng.fit(posts[1])
    oracle.fit(posts[1])

    with MicroBatcher(eng, max_batch=4, batch_timeout_ms=50,
                      autostart=False) as mb:
        subs = [
            mb.submit(graphs[0]),                       # cache-warm, no kwargs
            mb.submit(posts[1], init_active=fronts[1]),  # frontier + cache hit
            mb.submit(posts[2], init_active=fronts[2]),  # frontier + cache MISS
        ]
        mb.start()
        results = [s.result(timeout=300) for s in subs]
    assert [s.batch_size for s in subs] == [3, 3, 3]

    solo = [oracle.fit(graphs[0]),
            oracle.fit(posts[1], init_active=fronts[1]),
            oracle.fit(posts[2], init_active=fronts[2])]
    for i, (got, want) in enumerate(zip(results, solo)):
        assert np.array_equal(got.labels, want.labels), i
        assert got.lpa_iterations == want.lpa_iterations, i
    assert results[0].warm_started and results[1].warm_started
    assert not results[2].warm_started   # miss -> frontier dropped, cold


def test_context_manager_drains_on_exit():
    eng = fresh_engine(backend="segment")
    with MicroBatcher(eng, max_batch=8, batch_timeout_ms=5) as mb:
        subs = [mb.submit(erdos_renyi(50, 3.0, seed=i)) for i in range(3)]
    assert all(s.done() for s in subs)
    assert sum(mb.batch_sizes) == 3


def test_serve_communities_driver_smoke():
    from repro.launch.serve import serve_communities
    records, summary = serve_communities(
        num_requests=6, backend="segment", size_classes=(60, 90),
        avg_degree=4.0, max_batch=4, batch_timeout_ms=20)
    assert summary["requests"] == 6
    assert sum(k * v for k, v in summary["batch_size_hist"].items()) == 6
    assert summary["edges_per_s"] > 0
    assert summary["p95_ms"] >= summary["p50_ms"] > 0
    assert len(records) == 6
    assert all(r["latency_s"] is not None for r in records)
