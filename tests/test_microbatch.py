"""Micro-batching scheduler: batch formation, result parity, serving driver."""
import numpy as np
import pytest

from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi
from repro.launch.microbatch import MicroBatcher


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


def test_batches_form_and_results_match_solo_fits():
    graphs = [erdos_renyi(n, 4.0, seed=i)
              for i, n in enumerate((60, 80, 60, 90, 70))]
    eng = fresh_engine(backend="segment")
    mb = MicroBatcher(eng, max_batch=2, batch_timeout_ms=50, autostart=False)
    subs = [mb.submit(g) for g in graphs]
    mb.start()
    results = [s.result(timeout=300) for s in subs]
    mb.close()

    # deterministic drain of a pre-enqueued burst: ceil-chunks of max_batch
    assert mb.batch_sizes == [2, 2, 1]
    assert [s.batch_size for s in subs] == [2, 2, 2, 2, 1]
    assert all(s.latency_s is not None and s.latency_s > 0 for s in subs)
    ref = fresh_engine(backend="segment")
    for g, r in zip(graphs, results):
        assert np.array_equal(r.labels, ref.fit(g).labels)

    stats = mb.stats()
    assert stats["requests"] == 5 and stats["batches"] == 3
    assert stats["batch_size_hist"] == {1: 1, 2: 2}
    assert stats["p95_ms"] >= stats["p50_ms"] > 0


def test_submit_after_close_raises_and_close_is_idempotent():
    mb = MicroBatcher(fresh_engine(), max_batch=4, autostart=False)
    mb.close()
    mb.close()
    with pytest.raises(RuntimeError):
        mb.submit(erdos_renyi(20, 3.0, seed=0))


def test_worker_exception_propagates_to_waiters():
    class Boom:
        def fit_many(self, graphs, backend=None):
            raise RuntimeError("boom")

    mb = MicroBatcher(Boom(), max_batch=2, autostart=False)
    sub = mb.submit(erdos_renyi(20, 3.0, seed=0))
    mb.start()
    mb.close()
    with pytest.raises(RuntimeError, match="boom"):
        sub.result(timeout=30)


def test_context_manager_drains_on_exit():
    eng = fresh_engine(backend="segment")
    with MicroBatcher(eng, max_batch=8, batch_timeout_ms=5) as mb:
        subs = [mb.submit(erdos_renyi(50, 3.0, seed=i)) for i in range(3)]
    assert all(s.done() for s in subs)
    assert sum(mb.batch_sizes) == 3


def test_serve_communities_driver_smoke():
    from repro.launch.serve import serve_communities
    records, summary = serve_communities(
        num_requests=6, backend="segment", size_classes=(60, 90),
        avg_degree=4.0, max_batch=4, batch_timeout_ms=20)
    assert summary["requests"] == 6
    assert sum(k * v for k, v in summary["batch_size_hist"].items()) == 6
    assert summary["edges_per_s"] > 0
    assert summary["p95_ms"] >= summary["p50_ms"] > 0
    assert len(records) == 6
    assert all(r["latency_s"] is not None for r in records)
