"""Real-graph ingestion: parsers, preprocessing, CSR store, registry.

The acceptance contract this suite pins:
  * ``load_graph(fixture)`` is bit-identical (row_ptr/src/dst/wgt) to
    ``build_graph`` on the hand-written edge list, for both formats;
  * the second ``load_graph`` call is a cache hit that skips parsing;
  * ``Engine.fit`` on a loaded graph passes ``check_connected`` across
    the segment and tile backends.
"""
import numpy as np
import pytest

from repro.core.graph import build_graph, graph_fingerprint
from repro.io import (
    CsrStore,
    EdgeList,
    FormatError,
    PreprocessOptions,
    datasets,
    file_content_hash,
    load_graph,
    parse_edge_file,
    parse_mtx,
    parse_snap,
    preprocess,
    sniff_format,
    write_mtx,
    write_snap,
)
from repro.io.preprocess import connected_components

from pathlib import Path

FIXTURES = Path(__file__).parent / "fixtures"

# the graph hand-written into toy_general.mtx / toy.snap.txt
TOY_EDGES = np.array([[0, 1], [0, 2], [1, 2], [2, 3], [3, 4], [0, 4]])
TOY_WEIGHTS = np.array([1.5, 2.0, 1.0, 0.5, 2.25, 1.0])
# the graph hand-written into toy_symmetric.mtx (two bridged triangles)
TRI_EDGES = np.array([[0, 1], [1, 2], [0, 2], [3, 4], [4, 5], [3, 5],
                      [0, 3]])

CSR_FIELDS = ("row_ptr", "src", "dst", "wgt")


def assert_csr_identical(got, want):
    for f in CSR_FIELDS:
        x, y = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert x.dtype == y.dtype and np.array_equal(x, y), f


# --- parsers ---------------------------------------------------------------

def test_parse_mtx_general_weighted():
    el = parse_mtx(FIXTURES / "toy_general.mtx")
    assert el.n == 5 and el.num_edges == 6
    assert np.array_equal(el.edges, TOY_EDGES)
    assert np.array_equal(el.weights, TOY_WEIGHTS)
    assert el.meta["field"] == "real"
    assert el.meta["symmetry"] == "general"


def test_parse_mtx_symmetric_pattern_mirrors():
    el = parse_mtx(FIXTURES / "toy_symmetric.mtx")
    assert el.n == 6 and el.weights is None
    assert el.meta["mirrored_entries"] == 7
    assert el.num_edges == 14  # 7 stored + 7 mirrored
    have = {tuple(sorted(e)) for e in el.edges.tolist()}
    assert have == {tuple(e) for e in TRI_EDGES.tolist()}


def test_parse_snap_with_comments():
    el = parse_snap(FIXTURES / "toy.snap.txt")
    assert el.n == 5 and el.num_edges == 6
    assert np.array_equal(el.edges, TOY_EDGES)
    assert el.weights is None
    assert el.meta["comment_lines"] == 3


def test_parse_snap_weighted_and_one_based():
    el = parse_snap(FIXTURES / "messy.snap.txt")
    assert el.num_edges == 7 and el.weights is not None
    # shifting a 0-based file with --one-based underflows to a negative
    # id, which the parser rejects loudly instead of mangling the graph
    with pytest.raises(FormatError):
        parse_edge_file(FIXTURES / "toy.snap.txt", fmt="snap",
                        one_based=True)


def test_sniff_format():
    assert sniff_format(FIXTURES / "toy_general.mtx") == "mtx"
    assert sniff_format(FIXTURES / "toy.snap.txt") == "snap"
    assert sniff_format(FIXTURES / "messy.snap.txt") == "snap"


def test_parse_mtx_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.mtx"
    bad.write_text("%%MatrixMarket matrix array real general\n2 2\n1\n")
    with pytest.raises(FormatError):
        parse_mtx(bad)
    # rectangular coordinate data is bipartite, not an adjacency matrix:
    # folding row and column ids into one vertex set would silently
    # connect unrelated entities
    rect = tmp_path / "rect.mtx"
    rect.write_text("%%MatrixMarket matrix coordinate real general\n"
                    "3 1000 1\n1 500 1.0\n")
    with pytest.raises(FormatError, match="rectangular"):
        parse_mtx(rect)
    truncated = tmp_path / "trunc.mtx"
    truncated.write_text(
        "%%MatrixMarket matrix coordinate pattern general\n3 3 5\n1 2\n2 3\n")
    with pytest.raises(FormatError):
        parse_mtx(truncated)


def test_chunked_parse_matches_single_block(tmp_path):
    """Tiny block sizes force many chunk boundaries mid-file; the parse
    must be identical to one-shot."""
    rng = np.random.default_rng(3)
    edges = rng.integers(0, 200, size=(500, 2))
    w = rng.uniform(0.1, 5.0, size=500)
    p = tmp_path / "chunky.mtx"
    write_mtx(p, edges, w, n=200)
    full = parse_mtx(p)
    tiny = parse_mtx(p, block_bytes=64)
    assert np.array_equal(full.edges, tiny.edges)
    assert np.array_equal(full.weights, tiny.weights)
    p2 = tmp_path / "chunky.snap.txt"
    write_snap(p2, edges, w)
    assert np.array_equal(parse_snap(p2).edges,
                          parse_snap(p2, block_bytes=64).edges)


# --- preprocessing ---------------------------------------------------------

def test_preprocess_messy_stats():
    el = parse_snap(FIXTURES / "messy.snap.txt")
    cleaned, stats = preprocess(el, PreprocessOptions(unit_weights=False))
    assert stats.raw_edges == 7
    assert stats.self_loops == 1
    assert stats.duplicates == 2     # (0,1) stored three ways
    assert stats.edges == 4
    assert stats.isolated_vertices == 1  # id 4 touches no edge
    # dedup keeps the max weight of (0,1): 2.5, not the 1.0+2.5+0.5 sum
    d = {tuple(e): w for e, w in zip(cleaned.edges.tolist(),
                                     cleaned.weights.tolist())}
    assert d[(0, 1)] == 2.5


def test_preprocess_unit_weights_default():
    el = parse_snap(FIXTURES / "messy.snap.txt")
    cleaned, stats = preprocess(el)
    assert cleaned.weights is None and not stats.weighted


def test_preprocess_largest_component_compacts():
    # two components: a path 0-1-2 and an edge 5-6; vertex 3,4 isolated
    el = EdgeList(edges=np.array([[0, 1], [1, 2], [5, 6]]),
                  weights=None, n=7)
    cleaned, stats = preprocess(
        el, PreprocessOptions(largest_component=True))
    assert stats.component_vertices_dropped == 4  # 3, 4, 5, 6
    # off-LCC vertices must not double-count as "isolated" after their
    # edges are removed: only 3 and 4 touch no edge in the cleaned graph
    assert stats.isolated_vertices == 2
    assert cleaned.n == 3
    assert cleaned.edges.tolist() == [[0, 1], [1, 2]]


def test_connected_components_vectorized():
    edges = np.array([[0, 1], [1, 2], [3, 4], [6, 5], [5, 3]])
    comp = connected_components(edges, 8)
    assert comp.tolist() == [0, 0, 0, 3, 3, 3, 3, 7]
    assert connected_components(np.zeros((0, 2), np.int64), 3).tolist() \
        == [0, 1, 2]


# --- load_graph + CSR store (the acceptance contract) ----------------------

def test_load_graph_mtx_bit_identical_and_cache_hit(tmp_path):
    ref = build_graph(TOY_EDGES, n=5)  # §4.1 default: unit weights
    g, rep = load_graph(FIXTURES / "toy_general.mtx",
                        cache_dir=tmp_path, return_report=True)
    assert not rep.cache_hit and rep.parse_seconds > 0
    assert_csr_identical(g, ref)
    assert graph_fingerprint(g) == graph_fingerprint(ref)

    g2, rep2 = load_graph(FIXTURES / "toy_general.mtx",
                          cache_dir=tmp_path, return_report=True)
    assert rep2.cache_hit and rep2.parse_seconds == 0.0  # no re-parse
    assert rep2.stats["raw_edges"] == 6  # stats replay from the entry
    assert_csr_identical(g2, ref)
    assert graph_fingerprint(g2) == graph_fingerprint(ref)


def test_load_graph_snap_bit_identical(tmp_path):
    ref = build_graph(TOY_EDGES, n=5)
    g, rep = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path,
                        return_report=True)
    assert_csr_identical(g, ref)
    # both formats of the same graph build the same CSR
    g2 = load_graph(FIXTURES / "toy_general.mtx", cache_dir=tmp_path)
    assert_csr_identical(g2, ref)


def test_load_graph_symmetric_mtx(tmp_path):
    ref = build_graph(TRI_EDGES, n=6)
    g = load_graph(FIXTURES / "toy_symmetric.mtx", cache_dir=tmp_path)
    assert_csr_identical(g, ref)


def test_load_graph_weighted_options_key_separately(tmp_path):
    unit = load_graph(FIXTURES / "toy_general.mtx", cache_dir=tmp_path)
    wopt = PreprocessOptions(unit_weights=False)
    weighted, rep = load_graph(FIXTURES / "toy_general.mtx", wopt,
                               cache_dir=tmp_path, return_report=True)
    assert not rep.cache_hit  # different options -> different entry
    assert_csr_identical(weighted, build_graph(TOY_EDGES, TOY_WEIGHTS, n=5))
    assert not np.array_equal(np.asarray(unit.wgt),
                              np.asarray(weighted.wgt))


def test_load_graph_rejects_snap_only_kwargs_for_mtx(tmp_path):
    """n / one_based are meaningless for .mtx (its header declares both)
    — silently ignoring them while folding them into the cache key would
    fork duplicate store entries for byte-identical graphs."""
    with pytest.raises(ValueError, match="mtx"):
        load_graph(FIXTURES / "toy_general.mtx", cache_dir=tmp_path, n=50)
    with pytest.raises(ValueError, match="mtx"):
        load_graph(FIXTURES / "toy_general.mtx", cache_dir=tmp_path,
                   one_based=True)


def test_load_graph_cache_keys_on_content_not_name(tmp_path):
    src = (FIXTURES / "toy_general.mtx").read_text()
    a = tmp_path / "a.mtx"
    a.write_text(src)
    cache = tmp_path / "cache"
    _, rep1 = load_graph(a, cache_dir=cache, return_report=True)
    renamed = tmp_path / "renamed.mtx"
    renamed.write_text(src)
    _, rep2 = load_graph(renamed, cache_dir=cache, return_report=True)
    assert rep2.cache_hit and rep2.key == rep1.key  # same bytes, same entry
    a.write_text(src.replace("1 2 1.5", "1 2 7.5"))
    _, rep3 = load_graph(a, cache_dir=cache, return_report=True)
    assert not rep3.cache_hit  # content changed -> re-ingest


def test_load_graph_force_and_no_cache(tmp_path):
    _, rep = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path,
                        return_report=True)
    _, rep2 = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path,
                         force=True, return_report=True)
    assert not rep2.cache_hit and rep2.parse_seconds > 0
    _, rep3 = load_graph(FIXTURES / "toy.snap.txt", cache=False,
                         return_report=True)
    assert rep3.key == "" and not rep3.cache_hit


def test_store_repairs_corrupt_entry(tmp_path):
    _, rep = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path,
                        return_report=True)
    store = CsrStore(tmp_path)
    assert store.has(rep.key)
    (store.entry_dir(rep.key) / "arrays.bin").write_bytes(b"garbage")
    assert store.load(rep.key) is None  # corrupt entry reads as a miss
    g = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path)
    assert_csr_identical(g, build_graph(TOY_EDGES, n=5))
    # the re-ingest replaced the corrupt entry: next load is a hit again
    assert store.load(rep.key) is not None
    _, rep2 = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path,
                         return_report=True)
    assert rep2.cache_hit
    assert store.evict(rep.key) and not store.has(rep.key)


def test_fingerprint_continuity_across_store(tmp_path):
    """A cache-hit load re-attaches the saved fingerprint — no CRC
    recompute, and warm caches keyed on it stay valid across processes."""
    from unittest import mock
    ref = build_graph(TOY_EDGES, n=5)
    load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path)  # ingest
    g = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path)
    with mock.patch("zlib.crc32",
                    side_effect=AssertionError("fingerprint recomputed")):
        assert graph_fingerprint(g) == graph_fingerprint(ref)


def test_file_content_hash_streams(tmp_path):
    p = tmp_path / "blob.txt"
    p.write_bytes(b"x" * 1000)
    import hashlib
    assert file_content_hash(p) == hashlib.sha256(b"x" * 1000).hexdigest()


# --- engine integration ----------------------------------------------------

@pytest.mark.parametrize("backend", ("segment", "tile"))
def test_engine_fit_loaded_graph_connected(tmp_path, backend):
    """Acceptance: Engine.fit on a loaded real graph passes the
    connected-communities invariant on both single-device backends."""
    from repro.engine import Engine, EngineConfig
    g = load_graph(FIXTURES / "toy_symmetric.mtx", cache_dir=tmp_path)
    res = Engine(EngineConfig(backend=backend)).fit(g)
    assert res.check_connected(g) == 0.0
    assert res.num_communities >= 2  # the two triangles split


def test_engine_fit_accepts_path(tmp_path):
    from repro.engine import Engine, EngineConfig
    eng = Engine(EngineConfig(backend="segment"))
    res = eng.fit(str(FIXTURES / "toy_general.mtx"))
    assert res.labels.shape == (5,)
    with pytest.raises(TypeError):
        eng.fit(42)


# --- dataset registry ------------------------------------------------------

def test_registry_builtins_match_suite():
    assert {"web_rmat", "social_rmat", "road_grid", "kmer_sparse",
            "planted"} <= set(datasets.names())
    g = datasets.get("planted")
    assert g.n == 1024
    assert datasets.get("planted") is g  # memoized per process


def test_registry_file_entries(tmp_path):
    name = "toy_fixture_test"
    datasets.unregister(name)
    datasets.register_file(name, FIXTURES / "toy_general.mtx",
                           description="fixture", cache_dir=tmp_path)
    try:
        g, stats = datasets.get_with_stats(name)
        assert_csr_identical(g, build_graph(TOY_EDGES, n=5))
        assert stats["raw_edges"] == 6
        with pytest.raises(ValueError):
            datasets.register_file(name, "elsewhere.mtx")
    finally:
        datasets.unregister(name)


def test_registry_missing_file_and_unknown_name(tmp_path):
    name = "missing_file_test"
    datasets.unregister(name)
    datasets.register_file(name, tmp_path / "nope.mtx")
    try:
        with pytest.raises(FileNotFoundError):
            datasets.get(name)
    finally:
        datasets.unregister(name)
    with pytest.raises(KeyError):
        datasets.get("definitely-not-registered")


# --- transparent gzip decompression ----------------------------------------

def test_parse_gzip_snap_roundtrip():
    """The committed toy.snap.txt.gz parses identically to its plain
    sibling: same edges, same sniffed format, magic-byte detection."""
    plain = parse_snap(FIXTURES / "toy.snap.txt")
    gz = parse_snap(FIXTURES / "toy.snap.txt.gz")
    assert np.array_equal(gz.edges, plain.edges)
    assert gz.n == plain.n and gz.weights is None
    assert sniff_format(FIXTURES / "toy.snap.txt.gz") == "snap"


def test_gzip_mtx_and_content_sniff(tmp_path):
    import gzip
    raw = (FIXTURES / "toy_general.mtx").read_bytes()
    gz_path = tmp_path / "toy.mtx.gz"
    gz_path.write_bytes(gzip.compress(raw))
    el = parse_mtx(gz_path)
    assert np.array_equal(el.edges, TOY_EDGES)
    assert np.array_equal(el.weights, TOY_WEIGHTS)
    assert sniff_format(gz_path) == "mtx"
    # no helpful extension at all: content sniff reads through the gzip
    bare = tmp_path / "mystery"
    bare.write_bytes(gzip.compress(raw))
    assert sniff_format(bare) == "mtx"


def test_load_graph_gzip_bit_identical(tmp_path):
    """write -> gzip -> parse -> build round-trips bit-exactly through
    the store (gz bytes hash to their own cache key)."""
    import gzip
    g1 = load_graph(FIXTURES / "toy.snap.txt", cache_dir=tmp_path)
    g2, rep = load_graph(FIXTURES / "toy.snap.txt.gz", cache_dir=tmp_path,
                         return_report=True)
    assert not rep.cache_hit  # different bytes, own entry
    assert_csr_identical(g2, g1)


def test_write_gzip_parse_roundtrip(tmp_path):
    import gzip
    rng = np.random.default_rng(3)
    edges = rng.integers(0, 40, size=(60, 2))
    weights = rng.uniform(0.1, 5.0, size=60)
    plain = tmp_path / "rt.snap.txt"
    write_snap(plain, edges, weights)
    gz_path = tmp_path / "rt.snap.txt.gz"
    gz_path.write_bytes(gzip.compress(plain.read_bytes()))
    a = parse_snap(plain)
    b = parse_snap(gz_path)
    assert np.array_equal(a.edges, b.edges)
    assert np.array_equal(a.weights, b.weights)  # %.17g is bit-exact


# --- datasets.fetch ---------------------------------------------------------

def _file_url(path) -> str:
    return Path(path).resolve().as_uri()


def test_fetch_verifies_and_registers(tmp_path):
    name = "fetch_toy_test"
    datasets.unregister(name)
    src = FIXTURES / "toy_general.mtx"
    sha = file_content_hash(src)
    try:
        entry = datasets.fetch(name, _file_url(src), sha,
                               cache_dir=tmp_path / "dl",
                               description="offline file:// fixture")
        assert entry.kind == "file"
        dest = Path(entry.path)
        assert dest.is_file() and dest.parent == tmp_path / "dl"
        g = datasets.get(name)
        assert_csr_identical(g, build_graph(TOY_EDGES, n=5))
        # idempotent: second fetch re-verifies, does not re-download
        before = dest.stat().st_mtime_ns
        datasets.fetch(name, _file_url(src), sha, cache_dir=tmp_path / "dl",
                       overwrite=True)
        assert dest.stat().st_mtime_ns == before
    finally:
        datasets.unregister(name)


def test_fetch_checksum_mismatch_rejects(tmp_path):
    name = "fetch_bad_sha_test"
    datasets.unregister(name)
    src = FIXTURES / "toy_general.mtx"
    with pytest.raises(ValueError, match="checksum mismatch"):
        datasets.fetch(name, _file_url(src), "0" * 64,
                       cache_dir=tmp_path / "dl")
    # nothing registered, no partial file left behind
    assert name not in datasets.names()
    leftovers = [p for p in (tmp_path / "dl").glob("*") if p.is_file()]
    assert leftovers == []


def test_fetch_repairs_damaged_download(tmp_path):
    name = "fetch_repair_test"
    datasets.unregister(name)
    src = FIXTURES / "toy_general.mtx"
    sha = file_content_hash(src)
    dest = tmp_path / "dl" / "toy_general.mtx"
    dest.parent.mkdir(parents=True)
    dest.write_text("truncated garbage")
    try:
        datasets.fetch(name, _file_url(src), sha, cache_dir=tmp_path / "dl")
        assert file_content_hash(dest) == sha  # re-downloaded over damage
    finally:
        datasets.unregister(name)


def test_fetch_gzip_payload_loads(tmp_path):
    """fetch + gzip compose: a compressed corpus file registers as-is
    and loads through the transparent decompression."""
    import gzip
    name = "fetch_gz_test"
    datasets.unregister(name)
    src_gz = tmp_path / "toy.snap.txt.gz"
    src_gz.write_bytes(gzip.compress((FIXTURES / "toy.snap.txt").read_bytes()))
    try:
        datasets.fetch(name, _file_url(src_gz), file_content_hash(src_gz),
                       cache_dir=tmp_path / "dl", cache=False)
        g = datasets.get(name)
        assert_csr_identical(g, build_graph(TOY_EDGES, n=5))
    finally:
        datasets.unregister(name)
