"""apply_delta_patch: splice-based CSR patch, bit-parity with apply_delta."""
import numpy as np
import pytest

from repro.core.delta import (
    GraphDelta,
    apply_delta,
    apply_delta_patch,
    undirected_edges,
)
from repro.core.graph import build_graph, graph_fingerprint
from conftest import random_graph

FIELDS = ("row_ptr", "src", "dst", "wgt", "edge_mask", "kdeg")


def assert_bit_identical(a, b, ctx=""):
    assert (a.n, a.m_pad, a.num_edges) == (b.n, b.m_pad, b.num_edges), ctx
    for f in FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype, (ctx, f)
        assert np.array_equal(x, y), (ctx, f)
    assert graph_fingerprint(a) == graph_fingerprint(b), ctx


def test_patch_insert_delete_parity():
    g = build_graph(np.array([[0, 1], [1, 2], [2, 3], [3, 0]]), n=5)
    d = GraphDelta.make(insert=[[0, 2], [1, 4]], delete=[[2, 3]])
    assert_bit_identical(apply_delta(g, d), apply_delta_patch(g, d))


def test_patch_weight_merge_parity():
    """Merged weights accumulate float64 in build_graph's add order."""
    g = build_graph(np.array([[0, 1], [1, 2]]),
                    np.array([0.1, 0.2], np.float32), n=3)
    # duplicate insertions of an existing edge: orig + ins1 + ins2 order
    d = GraphDelta.make(insert=[[1, 0], [0, 1], [1, 2]],
                        weights=[0.3, 0.7, 0.111])
    assert_bit_identical(apply_delta(g, d), apply_delta_patch(g, d))


def test_patch_delete_then_reinsert_starts_fresh():
    g = build_graph(np.array([[0, 1], [1, 2]]),
                    np.array([5.0, 1.0], np.float32), n=3)
    d = GraphDelta.make(insert=[[0, 1]], weights=[0.25], delete=[[0, 1]])
    patched = apply_delta_patch(g, d)
    assert_bit_identical(apply_delta(g, d), patched)
    src = np.asarray(patched.src)[: patched.num_edges]
    dst = np.asarray(patched.dst)[: patched.num_edges]
    wgt = np.asarray(patched.wgt)[: patched.num_edges]
    idx = np.flatnonzero((src == 0) & (dst == 1))[0]
    assert wgt[idx] == np.float32(0.25)  # not 5.25: deletion wins first


def test_patch_vertex_growth_and_out_of_range_deletes():
    g = build_graph(np.array([[0, 1], [4, 5]]), n=10)
    # (2, 25) keys-collides with (4, 5) under a naive (u*n+v) scheme
    d = GraphDelta.make(insert=[[9, 12]], delete=[[2, 25]], num_vertices=11)
    assert_bit_identical(apply_delta(g, d), apply_delta_patch(g, d))
    assert apply_delta_patch(g, d).n == 13


def test_patch_empty_delta_returns_input_object():
    """The documented exception: a no-op delta skips the rebuild (which
    would re-round sum-merged duplicate weights through float32)."""
    g = random_graph(40, 3.0, seed=5, weighted=True)
    assert apply_delta_patch(g, GraphDelta.make()) is g
    grown = apply_delta_patch(g, GraphDelta.make(num_vertices=50))
    assert grown.n == 50  # pure growth is not a no-op
    assert_bit_identical(apply_delta(g, GraphDelta.make(num_vertices=50)),
                         grown)


def test_patch_shrink_rejected():
    g = build_graph(np.array([[0, 1]]), n=4)
    with pytest.raises(ValueError):
        apply_delta_patch(g, GraphDelta.make(num_vertices=2))


@pytest.mark.parametrize("weighted", (False, True))
def test_patch_randomized_parity_sweep(weighted):
    """Random graphs (duplicate weighted input edges on purpose — the
    kdeg float-order adversary) x random deltas: patch == rebuild."""
    rng = np.random.default_rng(11 + weighted)
    for trial in range(40):
        n = int(rng.integers(2, 50))
        g = random_graph(n, float(rng.uniform(0.5, 6.0)),
                         seed=int(rng.integers(1 << 30)), weighted=weighted)
        live, _ = undirected_edges(g)
        dels = live[rng.integers(0, len(live), size=3)].tolist() \
            if len(live) else []
        ins = rng.integers(0, n + 2, size=(3, 2)).tolist()
        if dels:
            ins.append(dels[0])  # delete + reinsert in one delta
        if len(live):
            ins += [live[0].tolist()] * 2  # double merge on one edge
        iw = rng.uniform(0.05, 3.0, size=len(ins)).astype(np.float32) \
            if weighted else None
        d = GraphDelta.make(insert=ins, delete=dels or None, weights=iw)
        if d.is_empty():
            continue
        assert_bit_identical(apply_delta(g, d), apply_delta_patch(g, d),
                             f"trial {trial}")


def test_patch_fingerprint_is_precomputed():
    """The patch attaches the fingerprint from host arrays — no lazy
    CRC recompute on first access (warm-cache lookups stay sync-free)."""
    from unittest import mock
    g = build_graph(np.array([[0, 1], [1, 2]]), n=3)
    patched = apply_delta_patch(g, GraphDelta.make(insert=[[0, 2]]))
    with mock.patch("zlib.crc32",
                    side_effect=AssertionError("lazy recompute")):
        fp = graph_fingerprint(patched)
    assert fp == graph_fingerprint(apply_delta(g, GraphDelta.make(
        insert=[[0, 2]])))
