"""Batched multi-graph detection: GraphBatch packing + fit_many parity.

The acceptance bar for the batched path is *bit parity*: for the
``segment`` and ``tile`` backends and every split mode,
``Engine.fit_many(graphs)[i]`` must produce exactly the labels (and
iteration counts) of ``Engine.fit(graphs[i])``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphBatch, disconnected_fraction
from repro.core.graph import build_graph, to_numpy_adj
from repro.engine import TRACE_LOG, CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi, karate_club, planted_partition
from conftest import random_graph

BATCH_BACKENDS = ("segment", "tile")
SPLITS = ("none", "lp", "lpp", "bfs_host")


def graph_mix():
    """Mixed sizes, duplicate sizes, a disconnected random graph, and an
    edgeless member (stays all-singletons through any split mode)."""
    return [
        erdos_renyi(150, 5.0, seed=1),
        karate_club()[0],
        random_graph(77, 4.0, seed=3),
        erdos_renyi(150, 5.0, seed=8),
        planted_partition(4, 25, 0.3, 0.01, seed=2)[0],
        build_graph(np.zeros((0, 2), np.int64), n=9),
    ]


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


# --- packing structure ---

def test_pack_is_disjoint_union():
    graphs = graph_mix()
    batch = GraphBatch.pack(graphs)
    assert batch.num_graphs == len(graphs)
    assert batch.total_vertices == sum(g.n for g in graphs)
    assert batch.total_edges == sum(g.num_edges for g in graphs)
    assert batch.graph.num_edges == batch.total_edges
    # graph_id labels every vertex with its owner
    assert np.array_equal(
        batch.graph_id,
        np.repeat(np.arange(len(graphs)), [g.n for g in graphs]))
    # adjacency is preserved member-by-member, offset by the pack
    adj = to_numpy_adj(batch.graph)
    for g, off in zip(graphs, batch.offsets[:-1]):
        want = to_numpy_adj(g)
        for v in range(g.n):
            got = sorted((d - int(off), w) for d, w in adj[v + int(off)])
            assert got == sorted(want[v])


def test_pack_handles_edgeless_and_empty_members():
    empty = build_graph(np.zeros((0, 2), np.int64), n=0)
    lonely = build_graph(np.zeros((0, 2), np.int64), n=1)
    edgeless = build_graph(np.zeros((0, 2), np.int64), n=7)
    batch = GraphBatch.pack([edgeless, empty, karate_club()[0], lonely])
    assert batch.total_vertices == 7 + 0 + 34 + 1
    assert batch.sizes.tolist() == [7, 0, 34, 1]
    labels = np.concatenate([np.zeros(7, np.int32), np.zeros(0, np.int32),
                             np.arange(34, dtype=np.int32),
                             np.zeros(1, np.int32)])
    out = batch.unpack(labels)
    assert [len(o) for o in out] == [7, 0, 34, 1]
    assert out[0].max() == 0 and out[2].tolist() == list(range(34))


def test_pack_empty_list_rejected():
    with pytest.raises(ValueError):
        GraphBatch.pack([])
    with pytest.raises(ValueError):
        GraphBatch.pack([karate_club()[0]]).unpack(np.zeros(3, np.int32))


# --- the parity suite ---

@pytest.mark.parametrize("backend", BATCH_BACKENDS)
@pytest.mark.parametrize("split", SPLITS)
def test_fit_many_parity(backend, split):
    """fit_many(graphs)[i] is bit-identical to fit(graphs[i])."""
    graphs = graph_mix()
    eng = fresh_engine(backend=backend, split=split)
    batched = eng.fit_many(graphs)
    assert len(batched) == len(graphs)
    for i, g in enumerate(graphs):
        single = eng.fit(g)
        assert np.array_equal(batched[i].labels, single.labels), (backend,
                                                                  split, i)
        assert batched[i].lpa_iterations == single.lpa_iterations
        assert batched[i].split_iterations == single.split_iterations
        assert batched[i].num_communities == single.num_communities
        assert batched[i].batch_size == len(graphs)
        assert batched[i].batch_index == i
        if split != "none":
            assert float(disconnected_fraction(
                g, jnp.asarray(batched[i].labels))) == 0.0


def test_fit_many_parity_shortcut_and_exact():
    graphs = graph_mix()[:3]
    for kw in ({"shortcut": True, "split": "lpp"}, {"bucketing": "exact"}):
        eng = fresh_engine(**kw)
        batched = eng.fit_many(graphs)
        for i, g in enumerate(graphs):
            assert np.array_equal(batched[i].labels, eng.fit(g).labels), kw


# --- batch plan caching ---

def test_same_batch_bucket_compiles_once():
    """Two different same-bucket batches -> one trace per batch stage."""
    mix1 = [erdos_renyi(150, 5.0, seed=1), erdos_renyi(90, 4.0, seed=2)]
    mix2 = [erdos_renyi(120, 5.0, seed=3), erdos_renyi(110, 4.0, seed=4)]
    eng = fresh_engine(backend="segment")

    before = TRACE_LOG.snapshot()
    r1 = eng.fit_many(mix1)
    mid = TRACE_LOG.snapshot()
    r2 = eng.fit_many(mix2)
    after = TRACE_LOG.snapshot()

    assert r1[0].bucket == r2[0].bucket
    assert not r1[0].cache_hit and r2[0].cache_hit
    first = {k: mid[k] - before.get(k, 0) for k in mid
             if mid[k] != before.get(k, 0)}
    second = {k: after[k] - mid.get(k, 0) for k in after
              if after[k] != mid.get(k, 0)}
    assert first == {"segment:batch_propagate": 1, "segment:batch_split": 1}
    assert second == {}, f"second same-bucket batch retraced: {second}"


def test_fit_many_sequential_fallback_without_capability():
    """Backends without supports_batch serve fit_many one graph at a time."""
    graphs = [erdos_renyi(60, 4.0, seed=1), erdos_renyi(64, 4.0, seed=2)]
    eng = fresh_engine()
    results = eng.fit_many(graphs, backend="sharded")
    assert [r.backend for r in results] == ["sharded", "sharded"]
    assert all(r.batch_size == 1 for r in results)
    ref = fresh_engine()
    for g, r in zip(graphs, results):
        assert np.array_equal(r.labels, ref.fit(g, backend="segment").labels)


@pytest.mark.parametrize("split", ("none", "lp", "bfs_host"))
def test_fit_many_sharded_fallback_parity(split):
    """The sharded sequential-fallback path is label-parity with the
    batch-capable backends, per split mode (lpp is rejected by the
    sharded backend, hence absent) — cold and warm-started alike."""
    graphs = [erdos_renyi(60, 4.0, seed=1), random_graph(45, 3.0, seed=7),
              karate_club()[0]]
    eng = fresh_engine(split=split)
    sharded = eng.fit_many(graphs, backend="sharded")
    for i, g in enumerate(graphs):
        for be in BATCH_BACKENDS:
            assert np.array_equal(sharded[i].labels,
                                  eng.fit(g, backend=be).labels), (split, be)

    # warm fallback: per-member init labels thread through sequential fits
    warm = [r.labels for r in sharded]
    sharded_w = eng.fit_many(graphs, init_labels=warm, backend="sharded")
    assert all(r.warm_started for r in sharded_w)
    for i, g in enumerate(graphs):
        assert np.array_equal(
            sharded_w[i].labels,
            eng.fit(g, init_labels=warm[i], backend="segment").labels)


def test_fit_many_trivial_inputs():
    eng = fresh_engine()
    assert eng.fit_many([]) == []
    g = karate_club()[0]
    (only,) = eng.fit_many([g])
    assert np.array_equal(only.labels, eng.fit(g).labels)


def test_fit_many_pro_rata_timings_and_metrics():
    graphs = graph_mix()[:3]
    eng = fresh_engine(compute_metrics=True)
    results = eng.fit_many(graphs)
    for r in results:
        # work-share estimates carry explicit prorated_* keys; only the
        # stages actually run per member (host split, compact) are real
        assert set(r.timings) == {"prorated_prepare",
                                  "prorated_propagation", "prorated_split",
                                  "split", "compact"}
        assert r.modularity is not None
        assert r.disconnected_fraction == 0.0
        # the aggregate properties fold both kinds in
        assert r.lpa_seconds == r.timings["prorated_propagation"]
    # pro-rata shares reassemble (approximately) into the batch totals
    total_prop = sum(r.timings["prorated_propagation"] for r in results)
    assert total_prop >= 0.0
