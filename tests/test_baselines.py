"""Baseline LPA implementations (the paper's comparison set)."""
import jax.numpy as jnp
import pytest

from repro.core import disconnected_fraction, modularity, split_lp
from repro.core.baselines import flpa_host, igraph_lpa_host, networkit_plp
from repro.graphgen import planted_partition, ring_of_cliques

BASELINES = {"flpa": flpa_host, "igraph": igraph_lpa_host,
             "networkit_plp": networkit_plp}


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_valid_labeling(name):
    g = ring_of_cliques(8, 5)
    lab = BASELINES[name](g)
    assert lab.shape == (g.n,)
    # every clique uniform under any reasonable LPA
    for q in range(8):
        assert len(set(lab[q * 5:(q + 1) * 5].tolist())) == 1


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_planted_quality(name):
    g, _ = planted_partition(6, 30, 0.35, 0.004, seed=5)
    lab = BASELINES[name](g)
    q = float(modularity(g, jnp.asarray(lab)))
    assert q > 0.4, (name, q)


def test_sl_fixes_baseline_disconnection():
    """Split-Last works as a post-processing step for *any* LPA — the
    paper's method applied to the baselines too."""
    for name, fn in BASELINES.items():
        for seed in range(6):
            g, _ = planted_partition(5, 25, 0.3, 0.01, seed=seed)
            lab = fn(g)
            fixed = split_lp(g, jnp.asarray(lab)).labels
            assert float(disconnected_fraction(g, fixed)) == 0.0
