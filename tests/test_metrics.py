"""NMI / ARI metric tests + GSL-LPA ground-truth recovery."""
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.metrics import adjusted_rand_index, normalized_mutual_info
from repro.core import gsl_lpa
from repro.graphgen import planted_partition


def test_identical_partitions():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert normalized_mutual_info(a, a) == pytest.approx(1.0)
    assert adjusted_rand_index(a, a) == pytest.approx(1.0)
    # relabeling-invariant
    b = np.array([5, 5, 9, 9, 1, 1])
    assert normalized_mutual_info(a, b) == pytest.approx(1.0)
    assert adjusted_rand_index(a, b) == pytest.approx(1.0)


def test_independent_partitions_near_zero():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 4, 4000)
    b = rng.integers(0, 4, 4000)
    assert abs(adjusted_rand_index(a, b)) < 0.02
    assert normalized_mutual_info(a, b) < 0.02


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(1, 5), st.integers(0, 1000))
def test_metric_bounds_and_symmetry(n, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, n)
    b = rng.integers(0, k, n)
    nmi = normalized_mutual_info(a, b)
    ari = adjusted_rand_index(a, b)
    assert -1e-9 <= nmi <= 1 + 1e-9
    assert -1.000001 <= ari <= 1 + 1e-9
    assert nmi == pytest.approx(normalized_mutual_info(b, a), abs=1e-9)
    assert ari == pytest.approx(adjusted_rand_index(b, a), abs=1e-9)


def test_gsl_lpa_recovers_planted_partition():
    g, truth = planted_partition(8, 50, p_in=0.35, p_out=0.002, seed=21)
    res = gsl_lpa(g, split="lp")
    nmi = normalized_mutual_info(res.labels, truth)
    ari = adjusted_rand_index(res.labels, truth)
    assert nmi > 0.9, nmi
    assert ari > 0.8, ari
