"""Property tests: GraphBatch.pack / unpack round-trip on random mixes."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow  # hypothesis suites ride the slow CI job

from repro.core import GraphBatch  # noqa: E402
from repro.core.graph import build_graph  # noqa: E402
from conftest import random_graph  # noqa: E402

# (n, avg_degree_tenths, seed) — n=0 and degree=0 members included on
# purpose: empty graphs, edgeless graphs, and duplicate sizes must all
# survive the round trip.
member = st.tuples(st.integers(0, 48), st.integers(0, 60),
                   st.integers(0, 10_000))


def make_graph(spec):
    n, deg_tenths, seed = spec
    if n == 0 or deg_tenths == 0:
        return build_graph(np.zeros((0, 2), np.int64), n=n)
    return random_graph(n, deg_tenths / 10.0, seed=seed)


@settings(max_examples=20, deadline=None)
@given(st.lists(member, min_size=1, max_size=6))
def test_pack_unpack_roundtrip(specs):
    graphs = [make_graph(s) for s in specs]
    batch = GraphBatch.pack(graphs)

    # structure: sizes/offsets/edge counts reassemble the member list
    assert batch.sizes.tolist() == [g.n for g in graphs]
    assert batch.edge_counts.tolist() == [g.num_edges for g in graphs]
    assert batch.total_vertices == sum(g.n for g in graphs)
    assert np.array_equal(np.diff(batch.offsets), batch.sizes)
    # the packed graph is a valid CSR expansion with no cross-graph edges
    src = np.asarray(batch.graph.src)[: batch.graph.num_edges]
    dst = np.asarray(batch.graph.dst)[: batch.graph.num_edges]
    if len(src):
        owner = batch.graph_id
        assert np.array_equal(owner[src], owner[dst])
        rp = np.asarray(batch.graph.row_ptr)
        assert np.array_equal(
            src, np.repeat(np.arange(batch.graph.n), rp[1:] - rp[:-1]))

    # round trip: arbitrary per-graph local labelings come back compacted
    rng = np.random.default_rng(0)
    per = [rng.integers(0, max(g.n, 1), size=g.n).astype(np.int32)
           for g in graphs]
    flat = (np.concatenate(per) if batch.total_vertices
            else np.zeros(0, np.int32))
    out = batch.unpack(flat)
    assert len(out) == len(graphs)
    for got, want in zip(out, per):
        expect = (np.unique(want, return_inverse=True)[1].astype(np.int32)
                  if len(want) else want)
        assert np.array_equal(got, expect)

    # uncompacted unpack is a pure slice
    raw = batch.unpack(flat, compact=False)
    for got, want in zip(raw, per):
        assert np.array_equal(got, want)
