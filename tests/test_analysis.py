"""The analyzer's own tests: rule IDs, file:line anchors, suppression
handling, baseline round-trips, CLI exit codes, and the repo-clean gate.

Fixture convention: files under tests/fixtures/lint/ mirror the hot-path
package layout (the linter maps them to rule-relative paths like
``core/...``); each positive fixture marks its expected finding lines
with a trailing ``# EXPECT-R00X`` comment.
"""
import json
import re
from pathlib import Path

import pytest

from repro.analysis import (
    Baseline,
    Finding,
    all_rules,
    lint_paths,
    lint_source,
    rule_relpath,
)
from repro.launch.lint import main as lint_main

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
_EXPECT = re.compile(r"#\s*EXPECT-(R\d{3})")

POSITIVE = sorted(p for p in FIXTURES.rglob("*.py")
                  if not p.stem.endswith(("_clean", "_suppressed")))
NEGATIVE = sorted(FIXTURES.rglob("*_clean.py"))


def _expected(path: Path) -> set[tuple[str, int]]:
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for rule in _EXPECT.findall(line):
            out.add((rule, lineno))
    return out


def _active(findings):
    return [f for f in findings if not f.suppressed]


@pytest.mark.parametrize("path", POSITIVE, ids=lambda p: p.stem)
def test_positive_fixture_flags_marked_lines(path):
    expected = _expected(path)
    assert expected, f"{path} has no EXPECT markers"
    rules = {r for r, _ in expected}
    assert len(rules) == 1, "each positive fixture triggers exactly one rule"
    findings = _active(lint_paths([path]))
    got = {(f.rule, f.line) for f in findings}
    assert got == expected, f"{path.name}: {got} != {expected}"
    relpath = rule_relpath(path)
    for f in findings:
        assert f.path == relpath
        assert f.line >= 1 and f.col >= 0


@pytest.mark.parametrize("path", NEGATIVE, ids=lambda p: p.stem)
def test_negative_fixture_stays_clean(path):
    assert lint_paths([path]) == []


def test_all_rules_covered_by_fixtures():
    seen = {r for p in POSITIVE for r, _ in _expected(p)}
    assert seen == {r.id for r in all_rules()} \
        == {"R001", "R002", "R003", "R004", "R005", "R006"}


def test_suppression_reported_not_active():
    path = FIXTURES / "core" / "r001_suppressed.py"
    findings = lint_paths([path])
    assert findings and all(f.suppressed for f in findings)
    assert {f.rule for f in findings} == {"R001"}


def test_suppression_same_line_and_wrong_tag():
    hazard = (
        "def drive(plan, g, labels, active):\n"
        "    while True:\n"
        "        labels, active, dn = plan.step(g, labels, active)\n"
        "        if int(dn) == 0:  {comment}\n"
        "            break\n"
    )
    ok = lint_source(hazard.format(comment="# lint: host-sync-ok — why"),
                     "core/x.py")
    assert ok and ok[0].suppressed
    wrong = lint_source(hazard.format(comment="# lint: retrace-ok"),
                        "core/x.py")
    assert wrong and not wrong[0].suppressed
    string_not_comment = lint_source(
        hazard.format(comment='+ len("lint: host-sync-ok")'), "core/x.py")
    assert string_not_comment and not string_not_comment[0].suppressed


def test_rules_scope_by_relpath():
    """The same hazard outside a hot-path module is not R001's business."""
    src = (
        "def drive(plan, g, labels, active):\n"
        "    while True:\n"
        "        labels, active, dn = plan.step(g, labels, active)\n"
        "        if int(dn) == 0:\n"
        "            break\n"
    )
    assert lint_source(src, "core/lpa.py")
    assert lint_source(src, "io/formats.py") == []


def test_syntax_error_becomes_finding():
    bad = lint_source("def broken(:\n", "core/x.py")
    assert len(bad) == 1 and bad[0].rule == "E000"


def test_rule_relpath_anchors():
    assert rule_relpath(Path("/r/src/repro/engine/backends/segment.py")) \
        == "engine/backends/segment.py"
    assert rule_relpath(Path("/r/tests/fixtures/lint/core/x.py")) \
        == "core/x.py"
    assert rule_relpath(Path("/elsewhere/thing.py")) == "thing.py"


def test_baseline_roundtrip(tmp_path):
    findings = _active(lint_paths([FIXTURES]))
    assert findings
    path = tmp_path / "baseline.json"
    n = Baseline.dump(findings, path)
    assert n == len({f.identity() for f in findings})
    baseline = Baseline.load(str(path))
    assert all(f in baseline for f in findings)
    # line-shifted twin still matches (identity is line-independent)
    f = findings[0]
    shifted = Finding(rule=f.rule, path=f.path, line=f.line + 40,
                      col=f.col, message=f.message)
    assert shifted in baseline
    assert Finding(rule=f.rule, path=f.path, line=f.line, col=f.col,
                   message="other") not in baseline


def test_cli_exit_codes(tmp_path, capsys):
    # fixtures carry positives -> strict fails, report-only passes
    assert lint_main([str(FIXTURES), "--strict"]) == 1
    assert lint_main([str(FIXTURES)]) == 0
    clean = FIXTURES / "core" / "r001_clean.py"
    assert lint_main([str(clean), "--strict"]) == 0
    assert lint_main(["--list-rules"]) == 0
    assert lint_main([str(FIXTURES), "--rules", "R999"]) == 2
    capsys.readouterr()
    assert lint_main([str(FIXTURES), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["new"] == len(payload["findings"]) > 0
    rules = {f["rule"] for f in payload["findings"]}
    assert rules == {"R001", "R002", "R003", "R004", "R005", "R006"}


def test_cli_baseline_gates_strict(tmp_path):
    baseline = tmp_path / "baseline.json"
    assert lint_main([str(FIXTURES), "--write-baseline",
                      "--baseline", str(baseline)]) == 0
    assert lint_main([str(FIXTURES), "--strict",
                      "--baseline", str(baseline)]) == 0


def test_vmem_ceiling_knob():
    path = FIXTURES / "kernels" / "r004_clean.py"
    assert lint_paths([path]) == []
    # 8*128*4 bytes/spec * 2 specs = 8 KiB; a 4 KiB ceiling trips it
    tight = all_rules(vmem_ceiling=4096)
    findings = _active(lint_paths([path], tight))
    assert findings and "VMEM" in findings[0].message


def test_repo_is_clean_under_strict():
    """The committed state of src/repro passes the strict gate: no
    active findings beyond the committed baseline."""
    import repro
    pkg = Path(repro.__file__).parent
    baseline_path = pkg / "analysis" / "baseline.json"
    baseline = Baseline.load(str(baseline_path)) \
        if baseline_path.exists() else Baseline()
    new = [f for f in _active(lint_paths([pkg])) if f not in baseline]
    assert new == [], "\n".join(f.format() for f in new)
