"""Flash-attention Pallas kernel vs the chunked-attention oracle.

Interpret mode executes the kernel body (incl. the causal block-skip
predication) on CPU; mode='pallas' on TPU is the identical code path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import chunked_attention


def _qkv(b, s, h, k, hd, skv=None, seed=0, dtype=jnp.bfloat16):
    skv = skv or s
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd), dtype)
    kk = jax.random.normal(jax.random.fold_in(key, 2), (b, skv, k, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, skv, k, hd), dtype)
    return q, kk, v


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)


@pytest.mark.parametrize("shape", [
    (1, 256, 4, 4, 64),      # MHA
    (2, 512, 8, 2, 64),      # GQA 4:1
    (1, 512, 4, 1, 128),     # MQA, hd=128
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(shape, causal):
    b, s, h, k, hd = shape
    q, kk, v = _qkv(b, s, h, k, hd)
    ref = ops.flash_attention(q, kk, v, causal=causal, mode="ref")
    got = ops.flash_attention(q, kk, v, causal=causal, mode="interpret")
    assert _rel_err(ref, got) < 8e-3      # one bf16 ulp ~ 0.4% relative


def test_flash_unpadded_lengths():
    """Wrapper pads ragged lengths; padded causal tail must not leak."""
    q, kk, v = _qkv(1, 300, 4, 4, 64, seed=3)
    ref = ops.flash_attention(q, kk, v, causal=True, mode="ref")
    got = ops.flash_attention(q, kk, v, causal=True, mode="interpret")
    assert _rel_err(ref, got) < 8e-3


def test_flash_cross_lengths():
    """Sq != Skv (cross/cache-style, non-causal, block-multiple)."""
    q, kk, v = _qkv(1, 256, 4, 4, 64, skv=512, seed=4)
    ref = ops.flash_attention(q, kk, v, causal=False, mode="ref")
    got = ops.flash_attention(q, kk, v, causal=False, mode="interpret")
    assert _rel_err(ref, got) < 8e-3


def test_block_skip_preserves_exactness():
    """The causal block-skip must be exact, not approximate: compare
    against full (non-skipping) evaluation via the oracle on a sequence
    spanning many blocks."""
    q, kk, v = _qkv(1, 1024, 2, 2, 64, seed=5)
    pos = jnp.arange(1024, dtype=jnp.int32)
    full = chunked_attention(q, kk, v, pos, pos, causal=True, chunk=1024)
    got = flash_attention_pallas(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(kk, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=True, block_q=128, block_k=128, interpret=True)
    assert _rel_err(full, jnp.moveaxis(got, 1, 2)) < 8e-3


def test_fp32_path():
    q, kk, v = _qkv(1, 256, 2, 2, 64, dtype=jnp.float32, seed=6)
    ref = ops.flash_attention(q, kk, v, causal=True, mode="ref")
    got = ops.flash_attention(q, kk, v, causal=True, mode="interpret")
    assert _rel_err(ref, got) < 1e-5
