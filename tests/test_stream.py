"""Streaming re-detection: batched warm-start parity, the engine's
warm-start cache, and StreamSession semantics.

The central parity obligation of the streaming path: for the batch-capable
backends and every split mode, warm batched re-detection over applied
deltas — ``fit_many(posts, init_labels=prev, init_active=frontiers)[i]``
— must be bit-identical to the solo warm ``fit(posts[i],
init_labels=prev[i], init_active=frontiers[i])``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    GraphDelta,
    affected_frontier,
    apply_delta,
    disconnected_fraction,
)
from repro.engine import CompileCache, Engine, EngineConfig
from repro.graphgen import erdos_renyi, evolving_sequence
from repro.launch.stream import StreamSession

BATCH_BACKENDS = ("segment", "tile")
SPLITS = ("none", "lp", "lpp", "bfs_host")


def fresh_engine(**kw):
    return Engine(EngineConfig(**kw), cache=CompileCache())


def make_stream_mix(sizes=(90, 60, 120), rounds=2, delta_edges=3):
    """Per-stream (base, deltas) traces of mixed sizes."""
    return [evolving_sequence(n, 4.0, rounds, delta_edges, seed=40 + i)
            for i, n in enumerate(sizes)]


# --- the warm batched parity suite (the PR's acceptance bar) ---

@pytest.mark.parametrize("backend", BATCH_BACKENDS)
@pytest.mark.parametrize("split", SPLITS)
def test_fit_many_warm_delta_parity(backend, split):
    """Warm batched re-detection over applied deltas is bit-identical to
    a solo warm fit on each post-delta graph — for every round of the
    trace, labels carried forward."""
    traces = make_stream_mix()
    eng = fresh_engine(backend=backend, split=split)
    prev = [eng.fit(base).labels for base, _ in traces]
    graphs = [base for base, _ in traces]

    for r in range(len(traces[0][1])):
        deltas = [ds[r] for _, ds in traces]
        graphs = [apply_delta(g, d) for g, d in zip(graphs, deltas)]
        fronts = [affected_frontier(d, g.n)
                  for d, g in zip(deltas, graphs)]
        batched = eng.fit_many(graphs, init_labels=prev, init_active=fronts)
        for i, g in enumerate(graphs):
            solo = eng.fit(g, init_labels=prev[i], init_active=fronts[i])
            assert np.array_equal(batched[i].labels, solo.labels), \
                (backend, split, r, i)
            assert batched[i].lpa_iterations == solo.lpa_iterations
            assert batched[i].split_iterations == solo.split_iterations
            assert batched[i].warm_started and solo.warm_started
            if split != "none":
                assert float(disconnected_fraction(
                    g, jnp.asarray(batched[i].labels))) == 0.0
        prev = [res.labels for res in batched]


def test_fit_many_mixed_warm_and_cold_members():
    """None entries in init_labels/init_active stay cold members; parity
    holds member-by-member."""
    g1, g2 = erdos_renyi(80, 4.0, seed=1), erdos_renyi(95, 4.0, seed=2)
    eng = fresh_engine()
    warm1 = eng.fit(g1).labels
    batched = eng.fit_many([g1, g2], init_labels=[warm1, None])
    assert batched[0].warm_started and not batched[1].warm_started
    assert np.array_equal(batched[0].labels,
                          eng.fit(g1, init_labels=warm1).labels)
    assert np.array_equal(batched[1].labels, eng.fit(g2).labels)


# --- warm-start cache regressions ---

def test_warm_cache_hits_and_misses_on_structural_change():
    """A delta changes the fingerprint -> no warm start until that exact
    structure has been fitted once; re-fits of either structure hit."""
    base = erdos_renyi(70, 4.0, seed=5)
    post = apply_delta(base, GraphDelta.make(insert=[[0, 9], [0, 11]]))
    eng = fresh_engine(warm_start="auto")
    assert not eng.fit(base).warm_started
    assert eng.fit(base).warm_started          # same structure -> hit
    assert not eng.fit(post).warm_started      # delta -> structural miss
    assert eng.fit(post).warm_started          # post structure now cached
    assert eng.fit(base).warm_started          # old entry still alive


def test_warm_cache_applies_to_fit_many_members():
    graphs = [erdos_renyi(60, 4.0, seed=i) for i in range(3)]
    eng = fresh_engine(warm_start="auto")
    first = eng.fit_many(graphs)
    assert not any(r.warm_started for r in first)
    second = eng.fit_many(graphs)
    assert all(r.warm_started for r in second)
    oracle = fresh_engine()
    for g, f, s in zip(graphs, first, second):
        # auto-warm member == explicit solo warm start from the same labels
        assert np.array_equal(
            s.labels, oracle.fit(g, init_labels=f.labels).labels)


def test_stale_labels_shape_mismatch_rejected():
    """Labels from the pre-delta graph must not silently truncate/pad
    when the vertex count changed — loud ValueError instead."""
    g = erdos_renyi(50, 4.0, seed=3)
    grown = apply_delta(g, GraphDelta.make(insert=[[0, 55]]))
    eng = fresh_engine()
    stale = eng.fit(g).labels
    with pytest.raises(ValueError, match="stale"):
        eng.fit(grown, init_labels=stale)
    with pytest.raises(ValueError, match=r"init_labels\[1\]"):
        eng.fit_many([g, grown], init_labels=[stale, stale])
    with pytest.raises(ValueError):
        eng.fit(g, init_labels=np.full(g.n, g.n + 2))       # out of range
    with pytest.raises(ValueError):
        eng.fit(g, init_active=np.ones(g.n - 1, dtype=bool))  # bad mask
    with pytest.raises(ValueError):
        eng.fit_many([g, grown], init_labels=[stale])       # wrong length


def test_frontier_without_warm_labels_degrades_to_full_cold_fit():
    """Regression: a frontier seed is only meaningful relative to warm
    labels — with none resolved (explicit None, or an auto-cache miss
    after eviction) it must be dropped, not restrict a cold sweep."""
    g = erdos_renyi(60, 4.0, seed=21)
    front = np.zeros(g.n, dtype=bool)
    front[:3] = True
    ref = fresh_engine().fit(g)

    res = fresh_engine().fit(g, init_active=front)
    assert not res.warm_started
    assert np.array_equal(res.labels, ref.labels)

    eng = fresh_engine(warm_start="auto", warm_cache_size=1)
    eng.fit(g)
    eng.fit(erdos_renyi(70, 4.0, seed=22))    # evicts g's cache entry
    res = eng.fit(g, init_active=front)       # miss -> full cold detect
    assert not res.warm_started
    assert np.array_equal(res.labels, ref.labels)

    batched = fresh_engine().fit_many([g], init_active=[front])
    assert np.array_equal(batched[0].labels, ref.labels)


def test_warm_cache_eviction_is_bounded():
    """A long session over many distinct structures never grows the
    cache beyond warm_cache_size (LRU eviction)."""
    eng = fresh_engine(warm_start="auto", warm_cache_size=3)
    graphs = [erdos_renyi(40 + 2 * i, 3.0, seed=i) for i in range(8)]
    for g in graphs:
        eng.fit(g)
        assert eng.stats()["warm_entries"] <= 3
    stats = eng.stats()
    assert stats["warm_capacity"] == 3 and stats["warm_entries"] == 3
    assert eng.fit(graphs[-1]).warm_started        # most recent survives
    assert not eng.fit(graphs[0]).warm_started     # oldest evicted
    with pytest.raises(ValueError):
        EngineConfig(warm_cache_size=0)


def test_engine_shared_across_threads_is_safe():
    """Regression: one Engine is shared by every session of the serving
    tier, but the warm-start LRU was an unlocked OrderedDict —
    ``move_to_end``/``popitem`` racing ``get``/``put`` from the batcher
    worker, direct ``fit`` callers, and ``stats()`` pollers corrupted it
    (RuntimeError: dict mutated during iteration / KeyError).  Hammer all
    three entry points from a thread pool with eviction pressure on."""
    from concurrent.futures import ThreadPoolExecutor

    eng = fresh_engine(warm_start="auto", warm_cache_size=3,
                       backend="segment")
    graphs = [erdos_renyi(60, 4.0, seed=i) for i in range(6)]
    for g in graphs:          # pay compiles up front, seed the cache
        eng.fit(g)

    def worker(k: int) -> None:
        rng = np.random.default_rng(k)
        for _ in range(10):
            op = int(rng.integers(3))
            g = graphs[int(rng.integers(len(graphs)))]
            if op == 0:
                res = eng.fit(g)
                assert len(res.labels) == g.n
            elif op == 1:
                h = graphs[int(rng.integers(len(graphs)))]
                for gr, r in zip((g, h), eng.fit_many([g, h])):
                    assert len(r.labels) == gr.n
            else:
                eng.stats()

    with ThreadPoolExecutor(max_workers=8) as pool:
        for f in [pool.submit(worker, k) for k in range(8)]:
            f.result(timeout=600)   # raises on any worker exception
    assert eng.stats()["warm_entries"] <= 3


# --- StreamSession ---

def test_stream_session_update_many_matches_solo_warm_fits():
    traces = make_stream_mix(sizes=(70, 50), rounds=2)
    eng = fresh_engine()
    oracle = fresh_engine()

    with StreamSession(eng, max_batch=8) as sess:
        added = sess.add_many({i: base for i, (base, _) in enumerate(traces)})
        ref_graphs = [base for base, _ in traces]
        ref_labels = [oracle.fit(g).labels for g in ref_graphs]
        for i in range(len(traces)):
            assert np.array_equal(added[i].labels, ref_labels[i])

        for r in range(2):
            deltas = {i: ds[r] for i, (_, ds) in enumerate(traces)}
            results = sess.update_many(deltas)
            for i, (_, ds) in enumerate(traces):
                ref_graphs[i] = apply_delta(ref_graphs[i], ds[r])
                front = affected_frontier(ds[r], ref_graphs[i].n)
                ref = oracle.fit(ref_graphs[i], init_labels=ref_labels[i],
                                 init_active=front)
                ref_labels[i] = ref.labels
                assert results[i].warm_started
                assert np.array_equal(results[i].labels, ref.labels), (r, i)
                assert np.array_equal(sess.labels(i), ref.labels)

        stats = sess.stats()
        assert stats["streams"] == 2 and stats["updates"] == 4
        assert stats["warm_updates"] == 4
        assert 0.0 < stats["mean_frontier_frac"] < 1.0


def test_stream_session_handles_vertex_growth_and_cold_mode():
    base, _ = evolving_sequence(40, 4.0, 1, 2, seed=9)
    grow = GraphDelta.make(insert=[[0, 45], [45, 46]])
    with StreamSession(fresh_engine(), max_batch=4) as sess:
        sess.add("g", base)
        res = sess.update("g", grow)
        assert sess.graph("g").n == 47 and len(res.labels) == 47
        assert res.warm_started
    with StreamSession(fresh_engine(), warm=False) as cold:
        cold.add("g", base)
        res = cold.update("g", grow)
        assert not res.warm_started
        assert cold.stats()["warm_updates"] == 0
    with pytest.raises(ValueError):
        with StreamSession(fresh_engine()) as sess:
            sess.add("g", base)
            sess.add("g", base)


def test_stream_session_churn_threshold_routes_patch_vs_rebuild(monkeypatch):
    """Delta application picks splice-patch vs rebuild at the measured
    EngineConfig.patch_churn_threshold, not a hard-coded fraction."""
    import repro.launch.stream as stream_mod
    from repro.core.delta import apply_delta as real_apply
    from repro.core.delta import apply_delta_patch as real_patch

    calls = []
    monkeypatch.setattr(stream_mod, "apply_delta",
                        lambda g, d: calls.append("rebuild") or real_apply(g, d))
    monkeypatch.setattr(stream_mod, "apply_delta_patch",
                        lambda g, d: calls.append("patch") or real_patch(g, d))

    base, _ = evolving_sequence(60, 4.0, 1, 2, seed=11)
    tiny = GraphDelta.make(insert=[[0, 1], [2, 3]])          # ~7% churn
    heavy = GraphDelta.make(insert=np.stack(
        [np.arange(0, 30), np.arange(30, 60)], axis=1))      # 100% churn

    with StreamSession(fresh_engine(), max_batch=4) as sess:
        sess.add("g", base)
        sess.update("g", tiny)
        assert calls == ["patch"]
        sess.update("g", heavy)
        assert calls == ["patch", "rebuild"]

    # a zero threshold forces the rebuild even for tiny deltas
    calls.clear()
    with StreamSession(fresh_engine(patch_churn_threshold=0.0),
                       max_batch=4) as sess:
        sess.add("g", base)
        sess.update("g", tiny)
        assert calls == ["rebuild"]


class _FlakyEngine:
    """Engine wrapper that fails any dispatch containing a graph with
    ``poison_n`` vertices while armed; passes everything else through."""

    def __init__(self, inner, poison_n: int):
        self._inner = inner
        self.config = inner.config
        self.poison_n = poison_n
        self.armed = True

    def fit_many(self, graphs, backend=None, **kw):
        if self.armed and any(g.n == self.poison_n for g in graphs):
            raise RuntimeError("transient fit failure")
        return self._inner.fit_many(graphs, backend=backend, **kw)


def test_update_many_partial_failure_commits_successes_only():
    """Regression: a member whose fit raised used to abort settlement
    mid-loop — earlier streams committed, later successful siblings
    dropped on the floor, and ``updates``/frontier counters recorded for
    streams whose state never advanced.  Now every success commits, the
    failed stream keeps its pre-delta state (a retry re-applies the same
    delta), and the batch raises StreamUpdateError carrying both maps."""
    from repro.core.graph import graph_fingerprint
    from repro.launch.stream import StreamUpdateError

    (base_a, deltas_a), (base_b, deltas_b) = make_stream_mix(
        sizes=(60, 80), rounds=1)
    flaky = _FlakyEngine(fresh_engine(), poison_n=base_b.n)
    oracle = fresh_engine()

    # max_batch=1: each stream dispatches alone, so only "b" fails
    with StreamSession(flaky, max_batch=1) as sess:
        flaky.armed = False
        sess.add_many({"a": base_a, "b": base_b})
        flaky.armed = True

        with pytest.raises(StreamUpdateError) as ei:
            sess.update_many({"a": deltas_a[0], "b": deltas_b[0]})
        err = ei.value
        assert set(err.errors) == {"b"}
        assert isinstance(err.errors["b"], RuntimeError)
        assert set(err.results) == {"a"}
        assert "1 of 2" in str(err) and "1 committed" in str(err)

        # "a" fully committed: post-delta graph + labels match the oracle
        post_a = apply_delta(base_a, deltas_a[0])
        ref_a = oracle.fit(post_a,
                           init_labels=oracle.fit(base_a).labels,
                           init_active=affected_frontier(deltas_a[0],
                                                         post_a.n))
        assert np.array_equal(err.results["a"].labels, ref_a.labels)
        assert np.array_equal(sess.labels("a"), ref_a.labels)
        assert sess.streams["a"].version == 1

        # "b" untouched: pre-delta structure, accounting never recorded
        assert graph_fingerprint(sess.graph("b")) == \
            graph_fingerprint(base_b)
        assert sess.streams["b"].version == 0
        stats = sess.stats()
        assert stats["updates"] == 1 and stats["warm_updates"] == 1

        # retrying the same delta after the fault clears just works
        flaky.armed = False
        res_b = sess.update("b", deltas_b[0])
        post_b = apply_delta(base_b, deltas_b[0])
        ref_b = oracle.fit(post_b,
                           init_labels=oracle.fit(base_b).labels,
                           init_active=affected_frontier(deltas_b[0],
                                                         post_b.n))
        assert np.array_equal(res_b.labels, ref_b.labels)
        assert sess.streams["b"].version == 1
        assert sess.stats()["updates"] == 2
