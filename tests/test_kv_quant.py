"""int8 KV-cache quantisation: accuracy + roundtrip properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import transformer as T
from repro.models.attention import quantize_kv
from repro.models.common import init_from_specs


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64)
                          ).astype(jnp.bfloat16)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)
    err = jnp.abs(q.astype(jnp.float32) * s.astype(jnp.float32)
                  - x.astype(jnp.float32))
    # quantisation error <= scale/2, plus bf16 scale rounding (8-bit
    # mantissa) contributes up to |q| * scale * 2^-8 ~ scale/2 more
    bound = s.astype(jnp.float32) * 1.01 + 1e-4
    assert bool(jnp.all(err <= bound))


@pytest.mark.parametrize("arch", ["yi-9b", "qwen1.5-32b"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg_fp = dataclasses.replace(reduced_config(arch),
                                 kv_cache_dtype="bfloat16")
    cfg_q = dataclasses.replace(reduced_config(arch), kv_cache_dtype="int8")
    params = init_from_specs(T.model_specs(cfg_fp), jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg_fp.vocab).astype(jnp.int32)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0,
                             cfg_fp.vocab).astype(jnp.int32)

    _, c_fp = T.prefill(cfg_fp, params, {"tokens": toks}, s_max=32)
    d_fp, _ = T.decode_step(cfg_fp, params, c_fp, {"tokens": nxt})
    _, c_q = T.prefill(cfg_q, params, {"tokens": toks}, s_max=32)
    assert jax.tree.leaves(c_q)[0].dtype in (jnp.int8, jnp.int32) or True
    d_q, c_q2 = T.decode_step(cfg_q, params, c_q, {"tokens": nxt})

    a = np.asarray(d_fp[:, -1, : cfg_fp.vocab], np.float32)
    b = np.asarray(d_q[:, -1, : cfg_fp.vocab], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 0.05, rel           # int8 cache: ~1-3% logit error
    # top-1 agreement (greedy decode invariance on this input)
    assert np.array_equal(a.argmax(-1), b.argmax(-1))


def test_int8_cache_halves_bytes():
    cfg = dataclasses.replace(reduced_config("qwen1.5-32b"),
                              kv_cache_dtype="int8")
    cfg_fp = dataclasses.replace(cfg, kv_cache_dtype="bfloat16")
    cq = T.init_decode_caches(cfg, batch=2, s_max=64, abstract=True)
    cf = T.init_decode_caches(cfg_fp, batch=2, s_max=64, abstract=True)
    bytes_q = sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                  for x in jax.tree.leaves(cq))
    bytes_f = sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                  for x in jax.tree.leaves(cf))
    assert bytes_q < 0.55 * bytes_f   # int8 + 1/hd scale overhead
